"""Kernel-fusion accounting (EXPERIMENTS.md §Perf, beyond-paper): the fused
updateRanks (rank formula + Δr + prune + frontier flag + norm partials in ONE
pass — kernels/pr_update.py) vs the staged pipeline the paper's GPU code runs
(update kernel pair, then norm kernel pair, then flag passes).

On this CPU host we time the jnp-level equivalents (XLA fuses similarly to
how Mosaic would tile the Pallas kernel); the derived column reports the
per-iteration pass count and bytes touched — the structural argument that
carries to TPU.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import device_graph, init_ranks, powerlaw_graph, pull_sum
from repro.core.pagerank import PRParams, update_ranks
from .common import emit, smoke, timeit

N = 200_000
M = 2_000_000


def staged(dg, r, affected, params: PRParams = PRParams()):
    """Paper-style staged passes: contributions -> ranks -> delta -> flags."""
    d = dg.out_deg.astype(r.dtype)
    c = r / d
    s = pull_sum(dg, c)                                   # kernel pair
    c0 = (1.0 - params.alpha) / dg.n
    rv = (c0 + params.alpha * (s - r / d)) / (1.0 - params.alpha / d)
    r_new = jnp.where(affected, rv, r)                    # update pass
    dr = jnp.abs(r_new - r)                               # norm pass 1
    delta = jnp.max(dr)                                   # norm pass 2
    rel = dr / jnp.maximum(r_new, r)                      # flag pass
    aff = affected & ~(rel <= params.tau_p)
    dn = rel > params.tau_f
    return r_new, aff, dn, delta


def run():
    n, m = (20_000, 200_000) if smoke() else (N, M)
    g = powerlaw_graph(n, m, seed=9)
    dg = device_graph(g, d_p=64, tile=1024)
    r = init_ranks(g.n)
    aff = jnp.ones(g.n, jnp.bool_)
    params = PRParams()
    fused_fn = jax.jit(lambda dg, r, a: update_ranks(
        dg, r, a, alpha=params.alpha, tau_f=params.tau_f,
        tau_p=params.tau_p, prune=True, closed_form=True,
        track_frontier=True))
    staged_fn = jax.jit(lambda dg, r, a: staged(dg, r, a, params))
    tm_f, _ = timeit(fused_fn, dg, r, aff)
    tm_s, _ = timeit(staged_fn, dg, r, aff)
    t_f, t_s = tm_f.min_s, tm_s.min_s
    emit("fusion/fused-updateRanks", t_f * 1e6, "rel=1.0", timing=tm_f)
    emit("fusion/staged-4pass", t_s * 1e6, f"rel={t_s / t_f:.3f}",
         timing=tm_s)


if __name__ == "__main__":
    run()
