"""Shared benchmark utilities: timing, graph generation, CSV output.

All PageRank benchmarks run the REAL jitted engine on this host (CPU device;
the Pallas kernels are validated separately in interpret mode — interpret
timing is meaningless). Numbers here are therefore CPU-relative: the paper's
*relationships* (DF-P vs Static vs ND vs DT speedups, error ordering) are the
reproduction target; absolute A100 numbers are not reproducible without the
hardware (EXPERIMENTS.md §Benchmarks).
"""
from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["timeit", "geomean", "emit"]


def timeit(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def geomean(xs):
    xs = np.asarray([max(x, 1e-12) for x in xs])
    return float(np.exp(np.mean(np.log(xs))))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
