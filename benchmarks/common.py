"""Shared benchmark utilities: timing, CSV + structured record collection.

All PageRank benchmarks run the REAL jitted engine on this host (CPU device;
the Pallas kernels are validated separately in interpret mode — interpret
timing is meaningless). Numbers here are therefore CPU-relative: the paper's
*relationships* (DF-P vs Static vs ND vs DT speedups, error ordering) are the
reproduction target; absolute A100 numbers are not reproducible without the
hardware (EXPERIMENTS.md §Benchmarks).

Two sinks, one call: ``emit`` prints the historical ``name,us_per_call,derived``
CSV row *and* appends a structured record to the module-level ``RECORDS``
list, which ``benchmarks.run`` drains into a ``repro.obs.report.RunReport``
(BENCH_obs.json) after the selected benches finish. Benches that have a full
``Timing`` or an iteration-trace summary attach them via the keyword args;
CSV output is unchanged either way.

``--smoke`` mode (set by ``benchmarks.run``) shrinks every bench to
CI-viable sizes via the ``smoke()`` predicate — same code paths, same
record schema, tiny graphs.
"""
from __future__ import annotations

import time
from typing import List, NamedTuple, Optional

import jax
import numpy as np

__all__ = ["Timing", "timeit", "geomean", "emit", "RECORDS",
           "reset_records", "set_smoke", "smoke"]


class Timing(NamedTuple):
    """One benchmark measurement: seconds over ``reps`` timed calls.

    ``min_s`` is the headline (noise-robust on a shared host: the minimum is
    the run least disturbed by the scheduler); mean/std are kept so the
    structured sink can show spread, not to replace the min. ``samples``
    holds every per-call wall-clock (seconds) so the v2 report can carry
    exact tail percentiles (empty on hand-built Timings: optional).
    """
    min_s: float
    mean_s: float
    std_s: float
    reps: int
    samples: tuple = ()


def timeit(fn, *args, warmup=1, iters=3, **kw):
    """Time ``fn(*args, **kw)`` -> (Timing, last_output).

    Blocks on the output every call so async dispatch can't leak work out of
    the timed region; ``warmup`` unmeasured calls absorb jit compilation.
    """
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    arr = np.asarray(ts)
    return Timing(min_s=float(arr.min()), mean_s=float(arr.mean()),
                  std_s=float(arr.std()), reps=len(ts),
                  samples=tuple(ts)), out


def geomean(xs) -> float:
    """Geometric mean; empty input -> 0.0 (a bench that measured nothing
    must not crash the whole suite with a numpy warning-turned-nan)."""
    xs = [max(float(x), 1e-12) for x in xs]
    if not xs:
        return 0.0
    return float(np.exp(np.mean(np.log(xs))))


#: structured records accumulated by ``emit`` for the current process;
#: drained by ``benchmarks.run`` into the BENCH_obs.json RunReport.
RECORDS: List[dict] = []

_SMOKE = False


def set_smoke(on: bool) -> None:
    global _SMOKE
    _SMOKE = bool(on)


def smoke() -> bool:
    """True when benches should shrink to CI smoke sizes."""
    return _SMOKE


def reset_records() -> None:
    RECORDS.clear()


def emit(name: str, us_per_call: float, derived: str = "", *,
         timing: Optional[Timing] = None,
         trace: Optional[dict] = None, hist=None) -> None:
    """Print the CSV row and record the structured equivalent.

    ``timing`` (when the bench used :func:`timeit`) contributes mean/std to
    the JSON record; without it the record carries the headline only.
    ``trace`` is a ``repro.obs.trace.trace_summary`` dict — the
    per-iteration linf/frontier series for this bench's solve.
    ``hist`` adds the v2 tail-latency columns (``us_p50/p95/p99/max``): a
    ``repro.obs.hist.Histogram``, a raw per-call sample list (seconds), or
    nothing — in which case ``timing.samples`` is used when it holds enough
    calls for a percentile to mean anything (>= 5).
    """
    print(f"{name},{us_per_call:.1f},{derived}")
    rec = {"name": name, "us_min": float(us_per_call), "derived": derived}
    if timing is not None:
        rec["us_mean"] = timing.mean_s * 1e6
        rec["us_std"] = timing.std_s * 1e6
        rec["reps"] = timing.reps
    if trace is not None:
        rec["trace"] = trace
    if hist is None and timing is not None and len(timing.samples) >= 5:
        hist = timing.samples
    if hist is not None:
        from repro.obs.hist import percentiles_from_samples
        pct = (hist.as_dict() if hasattr(hist, "as_dict")
               else percentiles_from_samples(hist))
        if pct.get("p50_s") is not None:
            for k in ("p50", "p95", "p99", "max"):
                rec[f"us_{k}"] = pct[f"{k}_s"] * 1e6
    RECORDS.append(rec)
