"""BENCH: single-device vs 1-D sharded vs 2-D sharded, static + streamed DF-P.

Forces a multi-device host platform (``--xla_force_host_platform_device_count``,
the SNIPPETS.md idiom) in a **subprocess**, so the rest of the benchmark
suite keeps seeing the real single device. Numbers on a shared CPU host
measure the *relationships* (collective overhead of 1-D vs 2-D vs none;
incremental sharded maintenance vs O(|E|) re-partition), not absolute
cluster performance.

Emitted rows:
  distributed/static/{single,1d,2d}        — one static solve, us/call
  distributed/stream/{sharded,repartition} — per-batch chained DF-P:
      `sharded` is the ShardedSnapshot path (touched-rows-only restage),
      `repartition` rebuilds + restages the full ShardedGraph every batch;
      the derived column carries rows_touched and the max per-batch L1 gap
      to a from-scratch static solve (ISSUE 2 acceptance: < 1e-8, no
      rebuild, no O(|E|) re-partition).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

N_DEV = 4
SCRIPT = textwrap.dedent("""
    import time
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import (PRParams, apply_batch, device_graph, init_ranks,
                            l1_error, static_pagerank, temporal_stream)
    from repro.core.distributed import (build_sharded, sharded_caps,
                                        distributed_static_pagerank,
                                        distributed_dfp_pagerank,
                                        initial_affected_sharded)
    from repro.core.distributed2d import build_sharded_2d, pagerank_2d
    from repro.stream import StreamSession, ingest

    ND = __ND__
    N, EDGES, BATCHES = 6_000, 120_000, 8
    assert len(jax.devices()) == ND, jax.devices()
    mesh = jax.make_mesh((ND,), ("data",))

    base, batches = temporal_stream(N, EDGES, n_batches=BATCHES, seed=7)

    def timeit(fn, iters=3):
        fn()                      # warmup (jit)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return min(ts)

    # ---- static: one solve per engine -----------------------------------
    dg = device_graph(base, d_p=32, tile=128)
    r0s = init_ranks(N)
    t = timeit(lambda: static_pagerank(dg, r0s)[0])
    print(f"distributed/static/single,{t * 1e6:.1f},nd=1")

    sg1 = build_sharded(base, ND, d_p=32, tile=128)
    r0 = jnp.full((ND, sg1.n_loc), 1.0 / N, jnp.float64)
    t = timeit(lambda: distributed_static_pagerank(mesh, sg1, r0)[0])
    print(f"distributed/static/1d,{t * 1e6:.1f},nd={ND}")

    r, c = ND // 2, 2
    if r == c:
        mesh2 = jax.make_mesh((r, c), ("data", "model"))
        sg2 = build_sharded_2d(base, r, c, d_p=8)
        rc, blk = sg2.out_deg.shape
        r0b = jnp.full((rc, blk), 1.0 / N, jnp.float64)
        t = timeit(lambda: pagerank_2d(mesh2, sg2, r0b)[0])
        print(f"distributed/static/2d,{t * 1e6:.1f},mesh={r}x{c}")

    # ---- streamed DF-P: incremental sharded session vs re-partition ------
    # tolerances below the session default: the ISSUE 2 acceptance bar
    # (every batch < 1e-8 L1 of a from-scratch solve) is a *sum* over |V|,
    # and BOTH endpoints stop within tau of the fixpoint — at |V|=6000 the
    # default tau=1e-10 alone leaves an ~1e-8 L1 gap on the table
    params = PRParams(tau=1e-12, tau_f=1e-10, tau_p=1e-10)
    sess = StreamSession(base, mesh=mesh, d_p=32, tile=128, params=params)
    caps0 = sharded_caps(sess.snap.sg)
    per_batch, max_err, max_rows = [], 0.0, 0
    for b in batches:
        t0 = time.perf_counter()
        jax.block_until_ready(sess.apply(b))
        per_batch.append(time.perf_counter() - t0)
        st = sess.history[-1]
        assert not st.snapshot.rebuilt, st.snapshot.rebuild_reason
        max_rows = max(max_rows, st.snapshot.rows_touched)
        err = l1_error(np.asarray(sess.flat_ranks()),
                       np.asarray(sess.static_reference()))
        max_err = max(max_err, err)
    assert sharded_caps(sess.snap.sg) == caps0   # shapes never changed
    assert max_err < 1e-8, max_err                # the acceptance bar
    t_inc = min(per_batch[1:])
    print(f"distributed/stream/sharded,{t_inc * 1e6:.1f},"
          f"max_rows_touched={max_rows};max_l1_vs_static={max_err:.3e};"
          f"batches={len(per_batch)}")

    # baseline: full O(|E|) re-partition + restage + the same DF-P engine
    sess2 = StreamSession(base, mesh=mesh, d_p=32, tile=128, params=params)
    g = base
    r_prev = sess2.ranks
    per_batch2 = []
    for b in batches:
        t0 = time.perf_counter()
        g = apply_batch(g, b)
        sgb = build_sharded(g, ND, d_p=32, tile=128)
        delta = ingest(b, N)
        db = delta.to_device()
        dv0, dn0 = initial_affected_sharded(ND, sgb.n_loc, db)
        r_prev, _ = distributed_dfp_pagerank(mesh, sgb, r_prev, dv0, dn0,
                                             sess2.params)
        jax.block_until_ready(r_prev)
        per_batch2.append(time.perf_counter() - t0)
    t_reb = min(per_batch2[1:])
    print(f"distributed/stream/repartition,{t_reb * 1e6:.1f},"
          f"speedup_of_sharded={t_reb / t_inc:.2f}")
""").replace("__ND__", str(N_DEV))


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=root,
                         capture_output=True, text=True, timeout=1800)
    from .common import emit
    if out.returncode != 0:
        emit("distributed/FAILED", 0.0, "see-stderr")
        sys.stderr.write(out.stderr[-2000:])
        return
    # re-emit the subprocess CSV through the shared sink so the rows land
    # in the structured report too (the subprocess has its own interpreter;
    # its RECORDS/registry are unreachable from here)
    for line in out.stdout.splitlines():
        if not line.strip():
            continue
        name, us, derived = line.split(",", 2)
        emit(name, float(us), derived)


if __name__ == "__main__":
    run()
