"""Benchmark harness: one module per paper table/figure.

  Table 1 / Fig. 2  -> bench_static     (throughput, edges/s)
  Table 2 / Fig. 3  -> bench_dynamic    (DF-P vs Static/ND/DT/DF, temporal)
  Fig. 4 / Fig. 5   -> bench_sweep      (random batch sweep: runtime + error)
  Fig. 1            -> bench_partition  (work-partitioning ablation)
  (beyond paper)    -> bench_fusion     (fused updateRanks accounting)
  (beyond paper)    -> bench_stream     (incremental snapshot vs rebuild)
  (beyond paper)    -> bench_distributed (single vs 1-D vs 2-D sharded,
                       static + streamed DF-P; forced host mesh, subprocess)

Prints ``name,us_per_call,derived`` CSV rows.
"""
import sys


def main() -> None:
    from . import (bench_static, bench_dynamic, bench_sweep, bench_partition,
                   bench_fusion, bench_stream, bench_distributed)
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    mods = {"static": bench_static, "dynamic": bench_dynamic,
            "sweep": bench_sweep, "partition": bench_partition,
            "fusion": bench_fusion, "stream": bench_stream,
            "distributed": bench_distributed}
    for key, mod in mods.items():
        if only and key != only:
            continue
        mod.run()


if __name__ == '__main__':
    main()
