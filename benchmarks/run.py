"""Benchmark harness: one module per paper table/figure.

  Table 1 / Fig. 2  -> bench_static     (throughput, edges/s)
  Table 2 / Fig. 3  -> bench_dynamic    (DF-P vs Static/ND/DT/DF, temporal)
  Fig. 4 / Fig. 5   -> bench_sweep      (random batch sweep: runtime + error)
  Fig. 1            -> bench_partition  (work-partitioning ablation)
  (beyond paper)    -> bench_fusion     (fused updateRanks accounting)
  (beyond paper)    -> bench_layout     (bucketed vs single-width ELL:
                       gathered-slot efficiency + per-iteration time)
  (beyond paper)    -> bench_stream     (incremental snapshot vs rebuild)
  (beyond paper)    -> bench_distributed (single vs 1-D vs 2-D sharded,
                       static + streamed DF-P; forced host mesh, subprocess)
  (beyond paper)    -> bench_frontier    (frontier-compacted active step vs
                       dense full sweep: density sweep + stream retraces)
  (beyond paper)    -> bench_guard       (guard-layer overhead on healthy
                       streams + recovery/restore latency)
  (beyond paper)    -> bench_obs2        (always-on obs layer overhead:
                       flight+histograms on vs REPRO_OBS_OFF baseline)

Prints ``name,us_per_call,derived`` CSV rows (unchanged format) and writes
the structured twin — a ``repro.obs/bench-v2`` RunReport with per-record
min/mean/std, tail percentiles (``us_p50/p95/p99``), parsed derived
metrics, iteration-trace summaries, the session's span/counter registry
and the flight-recorder summary — to ``--out`` (default BENCH_obs.json).
After the CSV a ``# pct`` block prints p50/p95 next to us_mean for every
record that carried samples. Gate a change against a previous run with
``python -m repro.obs.check`` (v2 gates us_p99 too).

Usage:
  python -m benchmarks.run [keys ...] [--smoke] [--out PATH] [--jsonl PATH]

``--smoke`` shrinks every bench to CI-viable sizes (same code paths, same
record schema); no keys = run everything.
"""
import argparse
import sys
from pathlib import Path

#: root-level per-PR perf snapshot (repro.obs/bench-v2, same payload as
#: --out) — the PR number tracks the repo's perf trajectory in-tree.
PR_JSON = Path(__file__).resolve().parents[1] / "BENCH_10.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("keys", nargs="*",
                    help="bench keys to run (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI sizes; same code paths and schema")
    ap.add_argument("--out", default="BENCH_obs.json",
                    help="structured report path ('' disables)")
    ap.add_argument("--pr-json", default=str(PR_JSON),
                    help="root-level per-PR perf snapshot ('' disables)")
    ap.add_argument("--jsonl", default="",
                    help="also write the JSONL form here")
    ap.add_argument("--name", default="bench",
                    help="report name recorded in the JSON header")
    args = ap.parse_args(argv)

    from . import common
    common.set_smoke(args.smoke)
    common.reset_records()

    from . import (bench_static, bench_dynamic, bench_sweep, bench_partition,
                   bench_fusion, bench_layout, bench_stream,
                   bench_distributed, bench_frontier, bench_guard,
                   bench_obs2)
    mods = {"static": bench_static, "dynamic": bench_dynamic,
            "sweep": bench_sweep, "partition": bench_partition,
            "fusion": bench_fusion, "layout": bench_layout,
            "stream": bench_stream, "distributed": bench_distributed,
            "frontier": bench_frontier, "guard": bench_guard,
            "obs2": bench_obs2}
    unknown = [k for k in args.keys if k not in mods]
    if unknown:
        ap.error(f"unknown bench keys {unknown}; choose from {list(mods)}")
    keys = args.keys or list(mods)

    print("name,us_per_call,derived")
    for key in keys:
        mods[key].run()

    pct_rows = [r for r in common.RECORDS if "us_p50" in r]
    if pct_rows:
        print("# pct: name, us_mean, us_p50, us_p95")
        for r in pct_rows:
            print(f"# pct,{r['name']},{r.get('us_mean', r['us_min']):.1f},"
                  f"{r['us_p50']:.1f},{r['us_p95']:.1f}")

    if args.out or args.jsonl or args.pr_json:
        from repro.obs.report import RunReport, parse_derived
        report = RunReport(name=args.name)
        for rec in common.RECORDS:
            report.add(rec["name"], us_min=rec["us_min"],
                       us_mean=rec.get("us_mean"),
                       us_std=rec.get("us_std"),
                       us_p50=rec.get("us_p50"), us_p95=rec.get("us_p95"),
                       us_p99=rec.get("us_p99"), us_max=rec.get("us_max"),
                       derived=parse_derived(rec.get("derived", "")),
                       trace=rec.get("trace"))
        report.attach_registry()
        report.attach_flight()
        if args.out:
            report.write_json(args.out)
            print(f"# wrote {args.out} ({len(report.benchmarks)} records)",
                  file=sys.stderr)
        if args.pr_json:
            report.write_json(args.pr_json)
            print(f"# wrote {args.pr_json}", file=sys.stderr)
        if args.jsonl:
            report.write_jsonl(args.jsonl)
    return 0


if __name__ == '__main__':
    sys.exit(main())
