"""BENCH: always-on observability overhead (ISSUE 10).

The flight recorder + span histograms run on EVERY batch of every stream —
they are only allowed to exist if they are effectively free. This bench
measures the whole always-on layer's price directly: the same stream is
replayed with the layer enabled (default) and disabled
(``set_obs_enabled(False)``, the ``REPRO_OBS_OFF`` baseline), reps
interleaved so scheduler noise hits both configurations equally.

  obs2/stream-obs-off      per-batch apply, always-on layer off (baseline)
  obs2/stream-obs-on       per-batch apply, flight + histograms live —
                           derived ``overhead=`` % (acceptance: < 2%)
  obs2/stream-slo          obs on + an SLOConfig judging every batch's
                           running p99 (the full v2 configuration)
  obs2/flight-emit         one FlightRecorder.emit, microbenched
  obs2/hist-add            one Histogram.add, microbenched

The stream rows carry exact per-batch tail percentiles (``us_p50/p95/p99``
in the v2 report) from the kept per-batch samples.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import time

import numpy as np

from repro.core import BatchUpdate, temporal_stream
from repro.obs import (FlightRecorder, Histogram, SLOConfig, obs_enabled,
                       set_obs_enabled)
from repro.stream import StreamSession
from .common import emit, smoke

N = 20_000
EDGES = 300_000
BATCH = 256
N_BATCHES = 16
REPS = 3
CAPS = dict(d_p=64, tile=256)


def _stream_batches(base, batches, **sess_kw):
    """One full stream replay; returns (total_s, per-batch seconds list)."""
    sess = StreamSession(base, **CAPS, **sess_kw)
    samples = []
    t0 = time.perf_counter()
    for b in batches:
        t1 = time.perf_counter()
        sess.apply(b)
        jax.block_until_ready(sess.ranks)
        samples.append(time.perf_counter() - t1)
    return time.perf_counter() - t0, samples


def run(n=N, edges=EDGES):
    batch, n_batches, reps = BATCH, N_BATCHES, REPS
    if smoke():
        n, edges, batch, n_batches, reps = 4_000, 40_000, 64, 8, 5
    base, raw = temporal_stream(n, edges, n_batches=1000, seed=7)
    src = np.concatenate([b.ins_src for b in raw])
    dst = np.concatenate([b.ins_dst for b in raw])
    batches = []
    off = 0
    for _ in range(n_batches):
        batches.append(BatchUpdate(
            del_src=np.zeros(0, np.int32), del_dst=np.zeros(0, np.int32),
            ins_src=src[off:off + batch], ins_dst=dst[off:off + batch]))
        off += batch

    # -- always-on layer on/off (interleaved; rep 0 = jit warmup) ------------
    was_on = obs_enabled()
    best = {"on": float("inf"), "off": float("inf"), "slo": float("inf")}
    kept = {}
    try:
        for rep in range(reps + 1):
            set_obs_enabled(False)
            dt, samples = _stream_batches(base, batches)
            if rep > 0 and dt < best["off"]:
                best["off"], kept["off"] = dt, samples
            set_obs_enabled(True)
            dt, samples = _stream_batches(base, batches)
            if rep > 0 and dt < best["on"]:
                best["on"], kept["on"] = dt, samples
            dt, samples = _stream_batches(
                base, batches,
                slo=SLOConfig(solve_p99_us=float("inf"), min_samples=1))
            if rep > 0 and dt < best["slo"]:
                best["slo"], kept["slo"] = dt, samples
    finally:
        set_obs_enabled(was_on)

    per_batch = {k: v / n_batches * 1e6 for k, v in best.items()}
    emit("obs2/stream-obs-off", per_batch["off"],
         f"batches={n_batches} batch={batch}", hist=kept["off"])
    for key, label in (("on", "obs-on"), ("slo", "slo")):
        ovh = 100.0 * (best[key] - best["off"]) / best["off"]
        emit(f"obs2/stream-{label}", per_batch[key],
             f"overhead={ovh:.2f}% batches={n_batches}", hist=kept[key])

    # -- primitive costs (the per-event price the stream rows amortize) ------
    fl = FlightRecorder(capacity=1024)
    k = 20_000 if not smoke() else 5_000
    t0 = time.perf_counter()
    for i in range(k):
        fl.emit("bench.tick", i=i)
    emit("obs2/flight-emit", (time.perf_counter() - t0) / k * 1e6,
         f"events={k} dropped={fl.dropped}")

    h = Histogram()
    t0 = time.perf_counter()
    for i in range(k):
        h.add(1e-4 + i * 1e-9)
    emit("obs2/hist-add", (time.perf_counter() - t0) / k * 1e6,
         f"samples={k} p99_s={h.percentile(99):.2e}")


if __name__ == "__main__":
    run()
