"""Table 1 / Fig. 2 analogue: Static PageRank throughput (edges/second).

The paper reports 471M edges/s on an A100 (sk-2005). We report this host's
CPU-device numbers for the same jitted engine across graph scales + the
processing rate, plus the multicore-vs-GPU-style comparison the paper makes
(Table 1 is vs Hornet/Gunrock — unavailable offline; we benchmark our own
engine at increasing |E| as the scaling evidence).
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (device_graph, init_ranks, powerlaw_graph,
                        random_graph, static_pagerank)
from repro.obs.trace import trace_summary
from .common import emit, smoke, timeit

CASES = [
    ("uniform-50k", random_graph, 50_000, 400_000),
    ("uniform-200k", random_graph, 200_000, 1_600_000),
    ("powerlaw-50k", powerlaw_graph, 50_000, 400_000),
    ("powerlaw-200k", powerlaw_graph, 200_000, 1_600_000),
]
SMOKE_CASES = [
    ("uniform-2k", random_graph, 2_000, 16_000),
    ("powerlaw-2k", powerlaw_graph, 2_000, 16_000),
]


def run():
    for name, maker, n, m in (SMOKE_CASES if smoke() else CASES):
        g = maker(n, m, seed=1)
        dg = device_graph(g, d_p=64, tile=1024)
        r0 = init_ranks(g.n)
        tm, (r, iters) = timeit(static_pagerank, dg, r0)
        # the timed path is untraced (production config); one extra traced
        # solve captures the convergence series for the structured sink
        _, t_iters, tb = static_pagerank(dg, r0, trace=True)
        iters = int(iters)
        assert int(t_iters) == iters
        t = tm.min_s
        eps = g.m * iters / t
        emit(f"static/{name}", t * 1e6,
             f"iters={iters};edges_per_s={eps:.3e};sum={float(r.sum()):.6f}",
             timing=tm, trace=trace_summary(tb, iters))


if __name__ == "__main__":
    run()
