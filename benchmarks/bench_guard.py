"""BENCH: guard-layer overhead + recovery latency (ISSUE 9).

The fault-tolerance layer must be near-free when nothing is wrong: on a
healthy stream the only additions are O(|Δ|) host-side validation, one
fused health reduction inside each jitted solve, and (journaled sessions)
one buffered append per batch. This bench measures exactly that, plus the
price of each recovery path when something IS wrong:

  guard/stream-unguarded   per-batch apply, ``guard=None`` (the baseline)
  guard/stream-guarded     per-batch apply, ``GuardConfig()`` — derived
                           ``overhead=`` % vs unguarded (acceptance: < 2%)
  guard/stream-journaled   guarded + write-ahead journal + periodic
                           checkpoints — the full crash-recovery config
  guard/recover-maxiter    one batch under a starved solve budget: watchdog
                           fires, ladder retries at full budget (rungs=)
  guard/recover-nan        one batch from NaN-poisoned ranks: nonfinite
                           bit fires after ONE sweep, ladder walks to the
                           static-recompute rung (rungs=)
  guard/restore            StreamSession.restore — checkpoint load + journal
                           replay back to bit-identical state (replayed=)

Timings are min-of-reps over full stream replays (sessions are stateful;
a batch cannot be re-applied in place), interleaved per rep so scheduler
noise hits both configurations equally.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import shutil
import tempfile
import time

import numpy as np

from repro.core import temporal_stream
from repro.guard import ChaosMonkey, GuardConfig
from repro.stream import StreamSession
from .common import emit, smoke

N = 20_000
EDGES = 300_000
BATCH = 256
N_BATCHES = 16
REPS = 3
CAPS = dict(d_p=64, tile=256)


def _stream_time(base, batches, **sess_kw):
    """Wall-clock for one full stream replay; returns (seconds, session)."""
    sess = StreamSession(base, **CAPS, **sess_kw)
    t0 = time.perf_counter()
    for b in batches:
        sess.apply(b)
    jax.block_until_ready(sess.ranks)
    return time.perf_counter() - t0, sess


def run(n=N, edges=EDGES):
    batch, n_batches, reps = BATCH, N_BATCHES, REPS
    if smoke():
        n, edges, batch, n_batches, reps = 4_000, 40_000, 64, 8, 3
    base, raw = temporal_stream(n, edges, n_batches=1000, seed=7)
    src = np.concatenate([b.ins_src for b in raw])
    dst = np.concatenate([b.ins_dst for b in raw])
    from repro.core import BatchUpdate
    batches = []
    off = 0
    for _ in range(n_batches):
        batches.append(BatchUpdate(
            del_src=np.zeros(0, np.int32), del_dst=np.zeros(0, np.int32),
            ins_src=src[off:off + batch], ins_dst=dst[off:off + batch]))
        off += batch

    # -- healthy-stream overhead (interleaved reps; rep 0 = jit warmup) ------
    configs = {
        "unguarded": dict(),
        "guarded": dict(guard=GuardConfig()),
    }
    jdirs = {}
    best = {k: float("inf") for k in configs}
    best["journaled"] = float("inf")
    for rep in range(reps + 1):
        for key, kw in configs.items():
            dt, _ = _stream_time(base, batches, **kw)
            if rep > 0:
                best[key] = min(best[key], dt)
        jdir = tempfile.mkdtemp(prefix="bench_guard_")
        dt, sess_j = _stream_time(base, batches, guard=GuardConfig(),
                                  journal_dir=jdir,
                                  checkpoint_every=max(2, n_batches // 2))
        sess_j.close()
        if rep > 0:
            best["journaled"] = min(best["journaled"], dt)
            jdirs[rep] = jdir
        else:
            shutil.rmtree(jdir)

    per_batch = {k: v / n_batches * 1e6 for k, v in best.items()}
    emit("guard/stream-unguarded", per_batch["unguarded"],
         f"batches={n_batches} batch={batch}")
    for key in ("guarded", "journaled"):
        ovh = 100.0 * (best[key] - best["unguarded"]) / best["unguarded"]
        emit(f"guard/stream-{key}", per_batch[key],
             f"overhead={ovh:.2f}% batches={n_batches}")

    # -- recovery latency ----------------------------------------------------
    chaos = ChaosMonkey(seed=5)

    def recover_maxiter():
        sess = StreamSession(base, **CAPS, guard=GuardConfig())
        chaos.force_nonconvergence(sess)
        t0 = time.perf_counter()
        sess.apply(batches[0])
        jax.block_until_ready(sess.ranks)
        return time.perf_counter() - t0, sess.history[-1]

    def recover_nan():
        sess = StreamSession(base, **CAPS, guard=GuardConfig())
        sess.ranks = chaos.poison_ranks(sess.ranks, mode="nan", k=1, idx=[7])
        t0 = time.perf_counter()
        sess.apply(batches[0])
        jax.block_until_ready(sess.ranks)
        return time.perf_counter() - t0, sess.history[-1]

    for name, fn in (("recover-maxiter", recover_maxiter),
                     ("recover-nan", recover_nan)):
        ts, st = [], None
        for rep in range(reps + 1):  # rep 0 warms the recovery-rung jits
            dt, st = fn()
            if rep > 0:
                ts.append(dt)
        assert st is not None and st.health != 0 and st.escalations >= 1, st
        emit(f"guard/{name}", min(ts) * 1e6,
             f"rungs={st.escalations} health={st.health}")

    # -- crash restore (newest journaled run from the overhead loop) ---------
    jdir = jdirs[max(jdirs)]
    ts = []
    replayed = n_batches - max(2, n_batches // 2)
    for _ in range(reps):
        t0 = time.perf_counter()
        sess = StreamSession.restore(jdir)
        jax.block_until_ready(sess.ranks)
        ts.append(time.perf_counter() - t0)
        sess.close()
    emit("guard/restore", min(ts) * 1e6, f"replayed={replayed}")
    for d in jdirs.values():
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    run()
