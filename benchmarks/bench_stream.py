"""BENCH: incremental snapshot maintenance vs. full rebuild, per batch.

Paper protocol (§5.1.4 temporal stream, 20k/300k): for each batch fraction,
apply PER_FRAC consecutive insertion batches two ways —

  * ``incremental``: StreamSession.apply — delta ingest + in-place
    DeviceSnapshot update + DF-P from previous ranks (everything resident);
  * ``rebuild``:     the pre-stream lifecycle — host apply_batch (O(|E|)
    np.isin/np.unique) + build_hybrid of both orientations + full device
    restage + the same DF-P engine;

and report end-to-end per-batch wall-clock plus the maintenance-only split.
The paper's DF-P speedup only survives end-to-end if maintenance is
o(|E|); this benchmark is the regression guard for that claim.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import time

import numpy as np

from repro.core import (apply_batch, build_hybrid, device_graph,
                        dfp_pagerank, dfp_pagerank_compact, init_ranks,
                        l1_error, static_pagerank, temporal_stream, to_device)
from repro.stream import StreamSession, ingest
from repro.stream.session import choose_engine
from .common import emit, geomean, smoke

N = 20_000
EDGES = 300_000
FRACS = (1e-5, 1e-4, 1e-3)
WARM = 2        # unmeasured leading batches: jit warmup + steady state
MEAS = 8        # measured batches per fraction (min = headline, noise-robust)
CAPS = dict(d_p=64, tile=256)


def run(n=N, edges=EDGES):
    fracs, warm, meas = FRACS, WARM, MEAS
    if smoke():
        n, edges, fracs, warm, meas = 4_000, 40_000, (1e-3,), 1, 2
    base, batches = temporal_stream(n, edges, n_batches=1000, seed=7)
    stream_src = np.concatenate([b.ins_src for b in batches])
    stream_dst = np.concatenate([b.ins_dst for b in batches])
    from repro.core import BatchUpdate
    for frac in fracs:
        B = max(1, int(frac * edges))
        bs = []
        off = 0
        for _ in range(warm + meas):
            bs.append(BatchUpdate(del_src=np.zeros(0, np.int32),
                                  del_dst=np.zeros(0, np.int32),
                                  ins_src=stream_src[off:off + B],
                                  ins_dst=stream_dst[off:off + B]))
            off += B

        # Both paths run INTERLEAVED, batch by batch, so scheduler noise on
        # a shared host lands on both equally. Maintenance is timed
        # synchronously (block on staged layouts) so async dispatch cannot
        # leak maintenance work into solve time; the solve is held to the
        # session's engine policy on both paths, so the comparison isolates
        # incremental snapshot maintenance vs the full rebuild.
        sess = StreamSession(base, **CAPS)
        params = sess.params
        g = base
        r_prev, _ = static_pagerank(device_graph(g, **CAPS),
                                    init_ranks(n), params)
        inc_total, inc_maintain = [], []
        reb_total, reb_maintain = [], []
        for i, b in enumerate(bs):
            # -- incremental: in-place snapshot update + resident DF-P ----
            t0 = time.perf_counter()
            delta = ingest(b, n)
            sess.snap.apply(delta)
            db = delta.to_device()
            jax.block_until_ready((sess.snap.dg, sess.snap.fwd_dg, db))
            t1 = time.perf_counter()
            if sess._choose_engine(delta) == "compact":
                r, _ = dfp_pagerank_compact(sess.snap, None, sess.ranks, db,
                                            params)
            else:
                r, _ = dfp_pagerank(sess.snap, sess.ranks, db, params)
            sess.ranks = jax.block_until_ready(r)
            t2 = time.perf_counter()

            # -- rebuild: apply_batch + build_hybrid x2 + restage + DF-P --
            t3 = time.perf_counter()
            g2 = apply_batch(g, b)
            dg = device_graph(g2, **CAPS)
            fwd = to_device(build_hybrid(g2.transpose(), **CAPS))
            delta = ingest(b, n)
            db = delta.to_device()
            jax.block_until_ready((dg, fwd, db))
            t4 = time.perf_counter()
            if choose_engine(delta, g2.out_degree(), n,
                             sess.compact_threshold) == "compact":
                r, _ = dfp_pagerank_compact(dg, fwd, r_prev, db, params)
            else:
                r, _ = dfp_pagerank(dg, r_prev, db, params)
            r_prev = jax.block_until_ready(r)
            t5 = time.perf_counter()
            g = g2
            if i < warm:
                continue
            inc_maintain.append(t1 - t0)
            inc_total.append(t2 - t0)
            reb_maintain.append(t4 - t3)
            reb_total.append(t5 - t3)
        err = l1_error(np.asarray(sess.ranks), np.asarray(r_prev))

        # headline = min over measured batches (the common.timeit estimator:
        # robust to scheduler noise on shared hosts); geomean kept as context
        t_inc, t_reb = min(inc_total), min(reb_total)
        m_inc, m_reb = min(inc_maintain), min(reb_maintain)
        emit(f"stream/frac={frac:g}/incremental", t_inc * 1e6,
             f"maintain_us={m_inc * 1e6:.1f};geo_us={geomean(inc_total) * 1e6:.1f};"
             f"maintain_speedup_vs_rebuild={m_reb / m_inc:.2f};"
             f"speedup_vs_rebuild={t_reb / t_inc:.2f};l1_vs_rebuild={err:.3e}")
        emit(f"stream/frac={frac:g}/rebuild", t_reb * 1e6,
             f"maintain_us={m_reb * 1e6:.1f};geo_us={geomean(reb_total) * 1e6:.1f}")


if __name__ == "__main__":
    run()
