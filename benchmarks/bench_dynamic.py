"""Table 2 / Fig. 3 analogue: DF-P vs Static/ND/DT/DF on a real-world-style
temporal stream (paper §5.1.4: load 90%, then insertion batches), reporting
per-approach runtime, speedup over Static, and L1 error vs the τ=1e-100
reference — the paper's headline claim is DF-P ≈ 2.1× Static here, with
error between ND and Static.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (apply_batch, batch_to_device, device_graph,
                        df_pagerank, df_pagerank_compact, dfp_pagerank,
                        dfp_pagerank_compact, dt_pagerank,
                        forward_device_graph, init_ranks, l1_error,
                        nd_pagerank, reference_pagerank, static_pagerank,
                        temporal_stream)
from repro.obs.trace import trace_summary
from .common import emit, geomean, smoke, timeit

N = 20_000
EDGES = 300_000
FRACS = (1e-5, 1e-4, 1e-3)   # of |E_T|, paper Fig. 3
PER_FRAC = 4


def run(n=N, edges=EDGES):
    fracs, per_frac = FRACS, PER_FRAC
    if smoke():
        n, edges, fracs, per_frac = 4_000, 40_000, (1e-3,), 2
    # Paper §5.1.4: warm 90% of the temporal stream, then apply batches of
    # B = frac*|E_T| consecutive stream edges for each batch size.
    base, batches = temporal_stream(n, edges, n_batches=1000, seed=7)
    stream_src = np.concatenate([b.ins_src for b in batches])
    stream_dst = np.concatenate([b.ins_dst for b in batches])
    caps = dict(d_p=64, tile=256)
    for frac in fracs:
        B = max(1, int(frac * edges))
        g = base
        dg = device_graph(g, **caps)
        r_prev, _ = static_pagerank(dg, init_ranks(g.n))
        times = {k: [] for k in ("static", "nd", "dt", "df", "dfp")}
        errs = {k: [] for k in times}
        dfp_trace = None
        off = 0
        for _ in range(per_frac):
            from repro.core import BatchUpdate
            b = BatchUpdate(del_src=np.zeros(0, np.int32),
                            del_dst=np.zeros(0, np.int32),
                            ins_src=stream_src[off:off + B],
                            ins_dst=stream_dst[off:off + B])
            off += B
            dg_prev = dg
            g = apply_batch(g, b)
            dg = device_graph(g, **caps)
            db = batch_to_device(b, g.n)
            ref = reference_pagerank(g)
            fwd = forward_device_graph(g, **caps)
            runs = {
                "static": lambda: static_pagerank(dg, init_ranks(g.n)),
                "nd": lambda: nd_pagerank(dg, r_prev),
                "dt": lambda: dt_pagerank(dg, dg_prev, r_prev, db),
                "df": lambda: df_pagerank_compact(dg, fwd, r_prev, db),
                "dfp": lambda: dfp_pagerank_compact(dg, fwd, r_prev, db),
            }
            out = {}
            for k, fn in runs.items():
                tm, (r, iters) = timeit(fn, warmup=1, iters=1)
                times[k].append(tm.min_s)
                errs[k].append(l1_error(np.asarray(r), ref))
                out[k] = r
            # untimed traced solve: the per-iteration linf/frontier series
            # for the structured sink (last measured batch wins)
            _, it_t, tb = dfp_pagerank_compact(dg, fwd, r_prev, db,
                                               trace=True)
            dfp_trace = trace_summary(tb, it_t)
            r_prev = out["dfp"]   # track like a production deployment
        t_static = geomean(times["static"])
        for k in times:
            t = geomean(times[k])
            emit(f"dynamic-temporal/frac={frac:g}/{k}", t * 1e6,
                 f"speedup_vs_static={t_static / t:.2f};"
                 f"l1err={geomean(errs[k]):.3e}",
                 trace=dfp_trace if k == "dfp" else None)


if __name__ == "__main__":
    run()
