"""Fig. 1 analogue: work-partitioning ablation for DF-P.

Paper variants -> our TPU translation:
  "Don't Partition"  -> single-format processing: d_p = max in-degree, i.e.
                        every vertex rides the lane-per-vertex ELL path
                        (padding waste = thread-divergence analogue);
  "Partition G'"     -> hybrid ELL + tiled-CSR split at d_p=64 (in-degree);
  "Partition G, G'"  -> hybrid split + d_p tuned per graph (the paper's
                        added out-degree partition speeds the expansion
                        kernels; our expansion is pull-based on the SAME
                        in-degree structures, so the tunable knob is d_p).
Reports total DF-P runtime per variant (geomean over batches).
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (apply_batch, batch_to_device, device_graph,
                        dfp_pagerank, init_ranks, powerlaw_graph,
                        random_batch, static_pagerank)
from .common import emit, geomean, smoke, timeit

N = 20_000
M = 300_000


def run(n=N, m=M):
    if smoke():
        n, m = 3_000, 30_000
    g0 = powerlaw_graph(n, m, seed=5)
    # paper variants -> layout knobs: "don't partition" = one format for all
    # (everything tiled, the block-per-vertex analogue); "partition G'" =
    # hybrid split at d_p=64; "partition G, G'" = hybrid + tuned d_p.
    variants = {
        "dont-partition": dict(d_p=0, tile=64),
        "partition-Gp": dict(d_p=64, tile=256),
        "partition-G-Gp": dict(d_p=32, tile=256),
    }
    results = {}
    for name, caps in variants.items():
        dg0 = device_graph(g0, **caps)
        r_prev, _ = static_pagerank(dg0, init_ranks(g0.n))
        ts = []
        for seed in range(3):
            b = random_batch(g0, 1e-4, seed=seed)
            g = apply_batch(g0, b)
            dg = device_graph(g, **caps)
            db = batch_to_device(b, g.n)
            tm, _ = timeit(dfp_pagerank, dg, r_prev, db, warmup=1, iters=1)
            ts.append(tm.min_s)
        results[name] = geomean(ts)
    base = results["dont-partition"]
    for name, t in results.items():
        emit(f"partition/{name}", t * 1e6, f"rel={t / base:.3f}")


if __name__ == "__main__":
    run()
