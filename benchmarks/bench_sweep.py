"""Fig. 4 / Fig. 5 analogue: large graph + random batch updates sweep.

Batch sizes 1e-6|E| .. 1e-2|E| (powers of 10), 80% insert / 20% delete,
self-loops maintained (paper §5.1.4). Reports runtime and L1 error for all
five approaches at each batch size. Expected paper relationships: DF-P ≈
3.1× Static for small-to-medium batches; DT *slower* than ND on uniformly
random updates; DF-P error between ND and Static, rising with batch size.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (apply_batch, batch_to_device, device_graph,
                        df_pagerank, df_pagerank_compact, dfp_pagerank,
                        dfp_pagerank_compact, dt_pagerank,
                        forward_device_graph, init_ranks, l1_error,
                        nd_pagerank, powerlaw_graph, reference_pagerank,
                        static_pagerank)
from .common import emit, smoke, timeit

N = 50_000
M = 500_000
FRACS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2)


def run(n=N, m=M, fracs=FRACS):
    from repro.core import random_batch
    if smoke():
        n, m, fracs = 4_000, 40_000, (1e-3,)
    g0 = powerlaw_graph(n, m, seed=3)
    caps = dict(d_p=64, tile=256)
    dg0 = device_graph(g0, **caps)
    r_prev, _ = static_pagerank(dg0, init_ranks(g0.n))
    for frac in fracs:
        b = random_batch(g0, frac, seed=int(1 / frac))
        g = apply_batch(g0, b)
        dg = device_graph(g, **caps)
        db = batch_to_device(b, g.n)
        ref = reference_pagerank(g)
        fwd = forward_device_graph(g, **caps)
        runs = {
            "static": lambda: static_pagerank(dg, init_ranks(g.n)),
            "nd": lambda: nd_pagerank(dg, r_prev),
            "dt": lambda: dt_pagerank(dg, dg0, r_prev, db),
            "df": lambda: df_pagerank_compact(dg, fwd, r_prev, db),
            "dfp": lambda: dfp_pagerank_compact(dg, fwd, r_prev, db),
            "df-dense": lambda: df_pagerank(dg, r_prev, db),
            "dfp-dense": lambda: dfp_pagerank(dg, r_prev, db),
        }
        t_static = None
        for k, fn in runs.items():
            tm, (r, iters) = timeit(fn, warmup=1, iters=1)
            t = tm.min_s
            if k == "static":
                t_static = t
            emit(f"sweep/frac={frac:g}/{k}", t * 1e6,
                 f"iters={int(iters)};speedup={t_static / t:.2f};"
                 f"l1err={l1_error(np.asarray(r), ref):.3e}", timing=tm)


if __name__ == "__main__":
    run()
