"""Degree-bucketed ELL vs single-width hybrid layout (beyond paper).

The paper's hybrid layout stores every low in-degree vertex at one ELL
width d_p; on a power-law degree distribution most rows are far narrower
than d_p, so most gathered slots are padding. The bucketed layout
(core.graph.choose_bucket_widths) stores each row at the narrowest chosen
width that fits it. This bench quantifies both sides of that trade on the
same graph:

  * slot accounting (`layout_slot_stats`): real edges vs gathered slots
    per layout — the padded-edge efficiency the repro.obs `layout.*`
    counters track;
  * per-iteration wall time of the dense DF-P engine body
    (`update_ranks`) on each layout — the time the saved gathers buy.

Rows: ``layout/single-width-*`` (forced widths=(d_p,)) and
``layout/bucketed-*`` (default build). The derived column carries the
gathered-slot ratio; acceptance target is >= 2x fewer gathered slots on
the power-law graph.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (build_hybrid, init_ranks, layout_slot_stats,
                        powerlaw_graph, pull_sum, to_device)
from repro.core.pagerank import update_ranks
from .common import emit, smoke, timeit

N = 200_000
M = 2_000_000
D_P = 64
TILE = 1024


def _iter_fn():
    return jax.jit(lambda dg, r, a: update_ranks(
        dg, r, a, alpha=0.85, tau_f=1e-6, tau_p=1e-6, prune=True,
        closed_form=True, track_frontier=True))


def run():
    n, m = (20_000, 200_000) if smoke() else (N, M)
    g = powerlaw_graph(n, m, seed=9)
    lay_single = build_hybrid(g, d_p=D_P, tile=TILE, widths=(D_P,))
    lay_bucket = build_hybrid(g, d_p=D_P, tile=TILE)
    st_s = layout_slot_stats(lay_single)
    st_b = layout_slot_stats(lay_bucket)
    ratio = st_s["gathered_slots"] / max(st_b["gathered_slots"], 1)

    r = init_ranks(g.n)
    aff = jnp.ones(g.n, jnp.bool_)
    pull = jax.jit(pull_sum)
    step = _iter_fn()
    results = {}
    for tag, lay in (("single-width", lay_single), ("bucketed", lay_bucket)):
        dg = to_device(lay)
        c = r / dg.out_deg.astype(r.dtype)
        tm_p, _ = timeit(pull, dg, c)
        tm_i, _ = timeit(step, dg, r, aff)
        results[tag] = (tm_p, tm_i)
    st = {"single-width": st_s, "bucketed": st_b}
    for tag in ("single-width", "bucketed"):
        tm_p, tm_i = results[tag]
        s = st[tag]
        emit(f"layout/{tag}-pull", tm_p.min_s * 1e6,
             f"gathered={s['gathered_slots']} real={s['real_edges']}",
             timing=tm_p)
        emit(f"layout/{tag}-iter", tm_i.min_s * 1e6,
             f"slot_ratio={ratio:.2f}" if tag == "bucketed" else "rel=1.0",
             timing=tm_i)


if __name__ == "__main__":
    run()
