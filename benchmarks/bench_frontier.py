"""BENCH: frontier-compacted active step vs dense full sweep (PR 8).

The dense DF-P engine body (`update_ranks`) gathers every slot of every
bucket and every CSR tile each iteration — O(|E|) regardless of how many
vertices are still converging. The compacted step (`active_frontier` +
`update_ranks_active`) stream-compacts the affected flags into per-bucket
active-row lists plus an active-tile list and runs the same math over the
lists only — O(frontier·degree). This bench sweeps frontier density on a
power-law graph and reports the crossover:

  frontier/dense-iter       full-sweep baseline (one jitted engine body)
  frontier/active-d=X       compacted step at density X, derived
                            ``speedup=``  (dense/active, same inputs) and
                            ``linf=``     (vs the kernels/ref.py oracle
                            chain: ell_pull_ref + csr_block_pull_ref +
                            pr_update_ref — parity target <= 1e-12)
  frontier/stream-retrace   engine re-traces across a chained
                            StreamSession, split ``first=`` (batch 1,
                            expected: the one compile) vs ``tail=``
                            (batches 2..N, expected 0: the never-shrink
                            caps keep the jit cache warm)

Acceptance (ISSUE 8): >= 3x iteration speedup at <= 5% density on the
smoke graph, linf <= 1e-12, tail re-traces == 0.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import functools

import jax.numpy as jnp
import numpy as np

from repro.core import (active_frontier, caps_for, device_graph, init_ranks,
                        random_graph, temporal_stream, update_ranks_active)
from repro.core.pagerank import update_ranks
from repro.kernels.ref import csr_block_pull_ref, ell_pull_ref, pr_update_ref
from repro.obs.spans import get_registry
from repro.stream import StreamSession
from .common import emit, smoke, timeit

# Uniform graphs (not power-law) for the density sweep: `powerlaw_graph`
# dedups repeated hub draws, so the requested edge budget collapses ~5-10x
# and the dense side under-represents the O(|E|) cost the compacted step
# is built to avoid. avg degree ~50 matches the paper's web-graph regime.
N = 200_000
M = 10_000_000
DENSITIES = (0.005, 0.02, 0.05, 0.2)
CAPS = dict(d_p=64, tile=256)
P = dict(alpha=0.85, tau_f=1e-9, tau_p=1e-9, prune=True, closed_form=True,
         track_frontier=True)


def _ref_update(dg, r, dv):
    """Full-sweep oracle from the kernels/ref.py primitives only."""
    deg = dg.out_deg.astype(r.dtype)
    c = r / deg
    out = jnp.zeros_like(r)
    for blk in dg.buckets:
        out = out.at[blk.rows].add(ell_pull_ref(c, blk.idx, blk.mask),
                                   mode="drop")
    hi = csr_block_pull_ref(c, dg.hi_tiles, dg.hi_tmask, dg.hi_rowmap,
                            dg.n_hi_cap)
    out = out.at[dg.hi_ids].add(hi, mode="drop")
    return pr_update_ref(out, r, deg, dv.astype(r.dtype), inv_n=1.0 / dg.n,
                         **{k: P[k] for k in ("alpha", "tau_f", "tau_p",
                                              "prune", "closed_form")})


@functools.partial(jax.jit, static_argnames=("caps",))
def _active_step(dg, r, dv, caps):
    af = active_frontier(dg.buckets, dg.hi_ids, dg.hi_rowmap, dv, caps)
    out = update_ranks_active(dg, r, dv, af, **P)
    return out, af.overflow


def _density_sweep(n, m):
    g = random_graph(n, m, seed=11)
    dg = device_graph(g, **CAPS)
    r = init_ranks(n)
    dense = jax.jit(lambda dg, r, a: update_ranks(dg, r, a, **P))
    rng = np.random.default_rng(5)
    for d in DENSITIES:
        k = max(1, int(d * n))
        rows = rng.choice(n, size=k, replace=False)
        dv_np = np.zeros(n, np.bool_)
        dv_np[rows] = True
        dv = jnp.asarray(dv_np)
        # headroom=2, not the session default 16: the sweep pins density,
        # so caps only need to cover the known per-bucket active counts
        caps = caps_for(dg, k, headroom=2)
        tm_d, out_d = timeit(dense, dg, r, dv)
        tm_a, (out_a, ovf) = timeit(_active_step, dg, r, dv, caps=caps)
        r_ref = _ref_update(dg, r, dv)[0]
        linf = float(jnp.max(jnp.abs(out_a[0] - r_ref)))
        linf_d = float(jnp.max(jnp.abs(out_d[0] - r_ref)))
        assert linf_d <= 1e-12, f"dense vs ref linf={linf_d}"
        if d == DENSITIES[0]:
            emit("frontier/dense-iter", tm_d.min_s * 1e6,
                 f"n={n};m={m}", timing=tm_d)
        emit(f"frontier/active-d={d:g}", tm_a.min_s * 1e6,
             f"speedup={tm_d.min_s / tm_a.min_s:.2f};linf={linf:.1e};"
             f"overflow={int(ovf)}", timing=tm_a)


def _stream_retrace(n, edges, n_batches):
    base, batches = temporal_stream(n, edges, n_batches=200, seed=7)
    reg = get_registry()
    c0 = reg.counter("frontier.retrace")
    # engine="dense" pins the caps-threaded driver for every batch so the
    # retrace series measures the frontier machinery, not engine handoffs.
    # warm = 2: batch shapes (padded delta arrays) stabilize after the
    # first two batches; the tail then isolates caps-driven re-traces.
    warm = 2
    sess = StreamSession(base, engine="dense", **CAPS)
    for b in batches[:warm]:
        sess.apply(b)
    first = reg.counter("frontier.retrace") - c0
    c1 = reg.counter("frontier.retrace")
    for b in batches[warm:n_batches]:
        sess.apply(b)
    tail = reg.counter("frontier.retrace") - c1
    growth = reg.counter("frontier.caps_growth")
    emit("frontier/stream-retrace", 0.0,
         f"first={first};tail={tail};caps_growth={growth};"
         f"batches={n_batches}")


def run():
    n, m = (20_000, 1_000_000) if smoke() else (N, M)
    _density_sweep(n, m)
    if smoke():
        _stream_retrace(4_000, 40_000, n_batches=6)
    else:
        _stream_retrace(20_000, 300_000, n_batches=12)


if __name__ == "__main__":
    run()
