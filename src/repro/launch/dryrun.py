import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below may import jax.

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell against the production mesh with ShapeDtypeStruct stand-ins (no
allocation), print memory_analysis / cost_analysis, and emit the roofline
terms (EXPERIMENTS.md §Dry-run / §Roofline read from this output).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --pagerank   # graph workload rows
"""
import argparse
import json
import sys
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config, list_configs, shape_applies
from ..models import LMModel
from ..models.model import batch_specs, cache_specs, input_specs, param_specs
from ..roofline.analysis import analyze, model_flops
from ..roofline.analytic import cost_for
from .mesh import HW, make_production_mesh

# --opt applies the EXPERIMENTS.md §Perf hillclimb lever set for the cell:
#   train cells  -> ZeRO-1 + sequence parallelism (+ pure-DP for small dense)
#   decode cells -> int8 KV cache + cache-T sharding over 'model'
_OPT_SMALL_DENSE = {"qwen2-1.5b", "smollm-360m", "qwen2-vl-2b", "qwen3-4b",
                    "rwkv6-1.6b", "recurrentgemma-2b"}


def _apply_opt(cfg, shape):
    import dataclasses
    if shape.kind == "train":
        if cfg.name in _OPT_SMALL_DENSE:
            # pure DP + ZeRO states + no grad accumulation: one weight pass
            # per step instead of 3·n_micro (weight re-reads dominate the
            # memory term once activations shrink to tokens/256 per device)
            return dataclasses.replace(cfg, pure_dp=True, zero1=True,
                                       grad_accum_dtype="bfloat16",
                                       microbatch=shape.global_batch)
        if cfg.moe is not None:
            moe = dataclasses.replace(cfg.moe, n_groups=8, group_top=4,
                                      capacity_factor=1.0,
                                      dispatch_dtype="float8_e4m3fn")
            return dataclasses.replace(cfg, zero1=True, seq_parallel=True,
                                       moe=moe)
        return dataclasses.replace(cfg, zero1=True, seq_parallel=True)
    if shape.kind == "decode":
        return dataclasses.replace(cfg, kv_cache_dtype="int8",
                                   shard_cache_t=True)
    return cfg


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh, *, verbose=True,
               opt=False):
    """Lower + compile one cell. Returns (compiled, RooflineReport)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applies(cfg, shape)
    if not ok:
        return None, why
    if opt:
        cfg = _apply_opt(cfg, shape)
    model = LMModel(cfg, mesh=mesh)
    aparams = model.abstract_params()
    pspecs = param_specs(cfg, aparams, mesh)
    chips = mesh.devices.size

    with mesh:
        if shape.kind == "train":
            aopt = jax.eval_shape(model.init_opt, aparams)
            ospecs = model.opt_partition(pspecs)
            bshapes, bspecs = batch_specs(cfg, mesh, shape.global_batch,
                                          shape.seq_len)
            fn = jax.jit(
                model.train_step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs),
                              _ns(mesh, bspecs)),
                out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), None),
                donate_argnums=(0, 1))
            lowered = fn.lower(aparams, aopt, bshapes)
        elif shape.kind == "prefill":
            bshapes, bspecs = batch_specs(cfg, mesh, shape.global_batch,
                                          shape.seq_len)
            fn = jax.jit(model.prefill_step,
                         in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)))
            lowered = fn.lower(aparams, bshapes)
        else:  # decode
            bshapes, bspecs = batch_specs(cfg, mesh, shape.global_batch, 1,
                                          decode=True)
            cshape, cspecs = cache_specs(cfg, mesh, shape.global_batch,
                                         shape.seq_len)
            fn = jax.jit(
                model.decode_step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                              _ns(mesh, bspecs), None),
                out_shardings=(None, _ns(mesh, cspecs)),
                donate_argnums=(1,))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fn.lower(aparams, cshape, bshapes, pos)
        compiled = lowered.compile()

    tag = "/opt" if opt else ""
    rep = analyze(f"{arch}/{shape_name}/"
                  f"{'x'.join(map(str, mesh.devices.shape))}{tag}",
                  compiled, chips, model_flops(cfg, shape))
    # analytic trip-count-aware terms (see roofline/analytic.py docstring for
    # why the compiled cost_analysis alone is insufficient on this backend)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    cost = cost_for(cfg, shape, mesh_shape)
    rep.hlo_flops = cost.flops
    rep.hlo_bytes = cost.hbm_bytes * chips
    rep.coll_bytes = cost.coll_bytes
    rep.per_device_mem = cost.mem_bytes
    if verbose:
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        print(f"--- {rep.name} ---")
        print(f"  memory_analysis(raw): args={ma.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={ma.temp_size_in_bytes/1e9:.2f}GB (loop-summed artifact; "
              f"see EXPERIMENTS.md)")
        print(f"  hlo-body(once-per-loop): flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  hlo collectives present: "
              f"{ {k: f'{v:.2e}' for k, v in rep.coll_breakdown.items() if v} }")
        print(f"  analytic: flops={cost.flops:.3e} hbm/dev={cost.hbm_bytes:.3e} "
              f"coll/dev={cost.coll_bytes:.3e} mem/dev={cost.mem_bytes/1e9:.2f}GB "
              f"fits={'YES' if cost.mem_bytes < HW.HBM_BYTES else 'NO'} "
              f"notes={cost.notes}")
        print(f"  terms(s): compute={rep.t_compute:.4f} "
              f"memory={rep.t_memory:.4f} collective={rep.t_collective:.4f} "
              f"-> bottleneck={rep.bottleneck} "
              f"roofline_frac={rep.roofline_fraction:.2f} "
              f"useful={rep.useful_ratio and round(rep.useful_ratio, 2)}")
    return compiled, rep


def lower_pagerank(mesh, n_vertices=1_048_576, d_p=64, tile=1024,
                   verbose=True, opt=False):
    """Dry-run the paper's workload itself on the production mesh: one DF-P
    iteration (all-gather + hybrid pull + fused update) at |V|=1M, |E|~16M."""
    from ..core.distributed import _FIELDS, _make_loop
    from ..core.pagerank import EllBlock, PRParams
    try:
        from jax import shard_map as shard_map_fn
    except ImportError:
        from jax.experimental.shard_map import shard_map as shard_map_fn

    nd = mesh.devices.size
    n_loc = n_vertices // nd
    avg_deg = 16
    hi_cap = max(1, n_loc // 100)
    t_cap = hi_cap * 4
    shard = P(tuple(mesh.axis_names))
    # degree buckets a mean-degree-16 power-law block typically selects:
    # most rows at width 8/32, a thin tail at the d_p crossover width
    widths = sorted({w for w in (8, 32) if w < d_p} | {d_p})
    caps = [n_loc] + [max(1, n_loc // (4 ** i))
                      for i in range(1, len(widths))]
    buckets = tuple(
        EllBlock(rows=jax.ShapeDtypeStruct((nd, cap), jnp.int32),
                 idx=jax.ShapeDtypeStruct((nd, cap, w), jnp.int32),
                 mask=jax.ShapeDtypeStruct((nd, cap, w), jnp.float32))
        for w, cap in zip(widths, caps))
    sgd = {
        "buckets": buckets,
        "hi_pos": jax.ShapeDtypeStruct((nd, hi_cap), jnp.int32),
        "hi_tiles": jax.ShapeDtypeStruct((nd, t_cap, tile), jnp.int32),
        "hi_tmask": jax.ShapeDtypeStruct((nd, t_cap, tile), jnp.float32),
        "hi_rowmap": jax.ShapeDtypeStruct((nd, t_cap), jnp.int32),
        "out_deg": jax.ShapeDtypeStruct((nd, n_loc), jnp.int32),
        "valid": jax.ShapeDtypeStruct((nd, n_loc), jnp.bool_),
    }
    r = jax.ShapeDtypeStruct((nd, n_loc), jnp.float32)
    flags = jax.ShapeDtypeStruct((nd, n_loc), jnp.bool_)
    loop = _make_loop(tuple(mesh.axis_names), PRParams(max_iter=1),
                      n_vertices, dfp=True, compact_frontier=opt)
    fn = shard_map_fn(loop, mesh=mesh,
                      in_specs=({k: shard for k in _FIELDS}, shard, shard,
                                shard),
                      out_specs=(shard, P()))
    with mesh:
        lowered = jax.jit(fn).lower(sgd, r, flags, flags)
        compiled = lowered.compile()
    edges = n_vertices * avg_deg
    rep = analyze(f"pagerank-dfp/{n_vertices}v/"
                  f"{'x'.join(map(str, mesh.devices.shape))}"
                  f"{'/opt' if opt else ''}",
                  compiled, nd, model_flops_val=2.0 * edges)
    if verbose:
        print(f"--- {rep.name} ---")
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        print(f"  collectives: { {k: f'{v:.2e}' for k, v in rep.coll_breakdown.items() if v} }")
        print(f"  terms(s): compute={rep.t_compute:.6f} "
              f"memory={rep.t_memory:.6f} collective={rep.t_collective:.6f} "
              f"-> {rep.bottleneck}")
    return compiled, rep


def lower_pagerank_2d(mesh, n_vertices=1_048_576, d_p=8, verbose=True):
    """Beyond-paper 2-D edge partition (core/distributed2d.py): per-device
    gather shrinks from V to V/r bytes. Uses the trailing square
    (data, model) = (16, 16) sub-mesh; 'pod' (if present) replicates."""
    from ..core.distributed2d import Sharded2D, _loop_2d
    from ..core.pagerank import PRParams
    try:
        from jax import shard_map as shard_map_fn
    except ImportError:
        from jax.experimental.shard_map import shard_map as shard_map_fn

    axes = mesh.axis_names
    row_axis, col_axis = axes[-2], axes[-1]
    r = mesh.shape[row_axis]
    c = mesh.shape[col_axis]
    rc = r * c
    n_pad = ((n_vertices + rc - 1) // rc) * rc
    v_r = n_pad // r
    blk = n_pad // rc
    shard = P((row_axis, col_axis))
    sgd = {
        "ell_idx": jax.ShapeDtypeStruct((rc, v_r, d_p), jnp.int32),
        "ell_mask": jax.ShapeDtypeStruct((rc, v_r, d_p), jnp.float32),
        "out_deg": jax.ShapeDtypeStruct((rc, blk), jnp.int32),
        "valid": jax.ShapeDtypeStruct((rc, blk), jnp.bool_),
    }
    rsh = jax.ShapeDtypeStruct((rc, blk), jnp.float32)
    fsh = jax.ShapeDtypeStruct((rc, blk), jnp.bool_)
    loop = _loop_2d(PRParams(max_iter=1), n_vertices, r, c, dfp=True,
                    row_axis=row_axis, col_axis=col_axis)
    fn = shard_map_fn(loop, mesh=mesh,
                      in_specs=({k: shard for k in sgd}, shard, shard, shard),
                      out_specs=(shard, P()))
    with mesh:
        compiled = jax.jit(fn).lower(sgd, rsh, fsh, fsh).compile()
    rep = analyze(f"pagerank-dfp-2d/{n_vertices}v/"
                  f"{'x'.join(map(str, mesh.devices.shape))}",
                  compiled, mesh.devices.size,
                  model_flops_val=2.0 * n_vertices * d_p)
    if verbose:
        print(f"--- {rep.name} ---")
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        print(f"  collectives: { {k: f'{v:.2e}' for k, v in rep.coll_breakdown.items() if v} }")
        print(f"  terms(s): compute={rep.t_compute:.6f} "
              f"memory={rep.t_memory:.6f} collective={rep.t_collective:.6f} "
              f"-> {rep.bottleneck}")
    return compiled, rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--pagerank", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the hillclimb lever set (see §Perf)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    results = []
    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    for mesh in meshes:
        mesh_name = "x".join(map(str, mesh.devices.shape))
        if args.pagerank:
            _, rep = lower_pagerank(mesh, opt=args.opt)
            results.append(rep)
            if args.opt:
                _, rep2 = lower_pagerank_2d(mesh)
                results.append(rep2)
            continue
        archs = list_configs() if args.all or not args.arch else [args.arch]
        shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
        for arch in archs:
            for shape in shapes:
                try:
                    compiled, rep = lower_cell(arch, shape, mesh,
                                               opt=args.opt)
                    if compiled is None:
                        print(f"--- {arch}/{shape}/{mesh_name}: {rep}")
                        results.append({"name": f"{arch}/{shape}/{mesh_name}",
                                        "skip": rep})
                    else:
                        results.append(rep)
                        del compiled
                except Exception as e:
                    traceback.print_exc()
                    print(f"!!! {arch}/{shape}/{mesh_name} FAILED: {e}")
                    results.append({"name": f"{arch}/{shape}/{mesh_name}",
                                    "error": str(e)[:500]})

    if args.json:
        out = []
        for r in results:
            if isinstance(r, dict):
                out.append(r)
            else:
                out.append({
                    "name": r.name, "chips": r.chips,
                    "hlo_flops": r.hlo_flops, "hlo_bytes": r.hlo_bytes,
                    "coll_bytes": r.coll_bytes,
                    "coll_breakdown": r.coll_breakdown,
                    "model_flops": r.model_flops,
                    "t_compute": r.t_compute, "t_memory": r.t_memory,
                    "t_collective": r.t_collective,
                    "bottleneck": r.bottleneck,
                    "roofline_fraction": r.roofline_fraction,
                    "useful_ratio": r.useful_ratio,
                    "per_device_mem": r.per_device_mem,
                })
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    n_err = sum(1 for r in results if isinstance(r, dict) and "error" in r)
    print(f"\n== {len(results)} cells, {n_err} failures ==")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
