"""Training launcher: mesh-sharded train loop for any --arch config.

On this CPU container it runs reduced (smoke) configs on a local mesh; on a
real pod the same entrypoint builds the production mesh and full config —
the flow (data -> sharded step -> checkpoint/restart) is identical.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_config, smoke_config
from ..train.loop import train
from .mesh import make_local_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_local_mesh(args.model_parallel)
    print(f"arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    params, history = train(cfg, steps=args.steps, batch=args.batch,
                            seq=args.seq, ckpt_dir=args.ckpt,
                            ckpt_every=args.ckpt_every, mesh=mesh)
    for h in history:
        print(h)
    print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
