"""Serving launcher: prefill a batch of prompts, then batched greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..data.pipeline import batch_for
from ..models import LMModel
from ..models import transformer as tfm
from .mesh import make_local_mesh


def serve(cfg, *, batch: int, prompt_len: int, gen: int, mesh=None, seed=0):
    """Returns (generated tokens [B, gen], tokens/sec)."""
    model = LMModel(cfg, mesh=mesh)
    params = model.init_params(jax.random.key(seed))
    prompts = batch_for(cfg, batch, prompt_len, 0, seed)
    total = prompt_len + gen
    cache = tfm.init_cache(cfg, batch, total)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    # prefill by stepping (correct for every cache kind incl. recurrent; a
    # fused full-sequence prefill writes the same cache — launch/dryrun
    # lowers that path; here we keep the universally-correct one)
    tok_key = "embeddings" if cfg.embed_inputs else "tokens"
    t0 = time.perf_counter()
    logits = None
    for t in range(prompt_len):
        piece = {tok_key: prompts[tok_key][:, t:t + 1]}
        logits, cache = decode(params, cache, piece,
                               jnp.asarray(t, jnp.int32))
    out = []
    nxt = jnp.argmax(logits[:, -1], axis=-1)
    for t in range(prompt_len, total):
        out.append(np.asarray(nxt))
        if cfg.embed_inputs:
            piece = {tok_key: jnp.take(params["embed"], nxt[:, None], axis=0)}
        else:
            piece = {tok_key: nxt[:, None]}
        logits, cache = decode(params, cache, piece,
                               jnp.asarray(t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], axis=-1)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    toks = np.stack(out, axis=1)
    return toks, batch * total / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    toks, tps = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                      gen=args.gen, mesh=make_local_mesh())
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
