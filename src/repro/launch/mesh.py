"""Production mesh construction.

Single pod: (16, 16) -> ('data', 'model')   [256 chips, v5e]
Multi-pod:  (2, 16, 16) -> ('pod', 'data', 'model')  [512 chips]

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run forces 512 host devices before first jax init; the
rest of the framework must see the real topology).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel: int = 1):
    """Whatever devices exist locally, split (data, model). For CPU smoke
    runs this is (1, 1)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


class HW:
    """TPU v5e per-chip constants used by the roofline analysis."""
    PEAK_FLOPS = 197e12        # bf16
    HBM_BW = 819e9             # bytes/s
    ICI_BW = 50e9              # bytes/s per link
    HBM_BYTES = 16e9
