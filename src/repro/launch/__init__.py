from .mesh import make_production_mesh, make_local_mesh, HW
__all__ = ["make_production_mesh", "make_local_mesh", "HW"]
