"""Ingest validation & quarantine (ISSUE 9 tentpole, piece 1).

``edge_keys`` packs (src, dst) into ``src * n + dst`` — an id outside
``[0, n)`` silently aliases another edge's key (``dst = n`` collides with
``(src+1, 0)``; negative ids wrap through Python's floor semantics), so a
single malformed pair used to corrupt the snapshot's sorted key set with no
error anywhere. This module puts a strict gate in front of the keying:

  * structural checks (always fatal): src/dst length mismatch, non-1-D
    arrays, non-integral dtypes — a batch whose *shape* is wrong is a
    programming error upstream, not streaming noise;
  * per-pair id-range checks, governed by ``policy``:
      - ``"raise"`` (the strict default `ingest` now applies): any
        out-of-range id raises ``ValidationError`` naming the offender;
      - ``"quarantine"`` (clamp-and-quarantine): offending pairs are
        *removed* from the batch, counted into the ``guard.quarantined``
        obs counter, and returned in a ``QuarantineReport`` for inspection
        — the stream keeps flowing on the clean remainder.

The checks are O(|Δ|) vectorized numpy on the host side of ingest — they
touch nothing device-resident and cost microseconds per batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core.graph import BatchUpdate
from ..obs.spans import get_registry as _obs

__all__ = ["ValidationError", "QuarantineReport", "validate_batch",
           "POLICIES"]

POLICIES = ("raise", "quarantine")


class ValidationError(ValueError):
    """A batch failed ingest validation under the strict policy."""


@dataclasses.dataclass(frozen=True)
class QuarantineReport:
    """What the quarantine removed from one batch (empty when clean)."""
    #: quarantined (src, dst) pairs per side, as given (pre-canonical)
    del_src: np.ndarray
    del_dst: np.ndarray
    ins_src: np.ndarray
    ins_dst: np.ndarray

    @property
    def size(self) -> int:
        return int(self.del_src.size + self.ins_src.size)

    def __bool__(self) -> bool:
        return self.size > 0


def _empty_report() -> QuarantineReport:
    z = np.zeros(0, np.int32)
    return QuarantineReport(z, z, z, z)


def _as_id_array(a, n: int, side: str, which: str) -> np.ndarray:
    """Structural gate: coerce to a 1-D integer ndarray or raise."""
    arr = np.asarray(a)
    if arr.ndim != 1:
        raise ValidationError(
            f"{side}.{which} must be 1-D, got shape {arr.shape}")
    if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
        # float ids are a corruption signature (a NaN-poisoned producer),
        # not a representation choice — reject even exact-integral floats
        raise ValidationError(
            f"{side}.{which} has non-integer dtype {arr.dtype}")
    return arr


def _side(src, dst, n: int, side: str) -> Tuple[np.ndarray, np.ndarray,
                                                np.ndarray]:
    src = _as_id_array(src, n, side, "src")
    dst = _as_id_array(dst, n, side, "dst")
    if src.shape[0] != dst.shape[0]:
        raise ValidationError(
            f"{side}: src/dst length mismatch ({src.shape[0]} vs "
            f"{dst.shape[0]})")
    bad = (src < 0) | (src >= n) | (dst < 0) | (dst >= n)
    return src, dst, bad


def validate_batch(batch: BatchUpdate, n: int, policy: str = "raise"
                   ) -> Tuple[BatchUpdate, QuarantineReport]:
    """Validate a raw ``BatchUpdate`` against vertex-id range ``[0, n)``.

    Returns ``(clean_batch, report)``. Structural violations always raise;
    id-range violations raise under ``policy="raise"`` and are stripped +
    reported under ``policy="quarantine"`` (``guard.quarantined`` counts
    pairs, ``guard.quarantined_batches`` counts batches that lost pairs).
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown validation policy: {policy!r}")
    d_s, d_d, d_bad = _side(batch.del_src, batch.del_dst, n, "del")
    i_s, i_d, i_bad = _side(batch.ins_src, batch.ins_dst, n, "ins")
    n_bad = int(d_bad.sum()) + int(i_bad.sum())
    if n_bad == 0:
        return batch, _empty_report()
    if policy == "raise":
        side = "del" if d_bad.any() else "ins"
        s, d, bad = (d_s, d_d, d_bad) if d_bad.any() else (i_s, i_d, i_bad)
        j = int(np.nonzero(bad)[0][0])
        raise ValidationError(
            f"{n_bad} out-of-range vertex id(s) in batch (n={n}); first: "
            f"{side} pair ({int(s[j])}, {int(d[j])})")
    report = QuarantineReport(
        del_src=d_s[d_bad].astype(np.int32, copy=False),
        del_dst=d_d[d_bad].astype(np.int32, copy=False),
        ins_src=i_s[i_bad].astype(np.int32, copy=False),
        ins_dst=i_d[i_bad].astype(np.int32, copy=False))
    obs = _obs()
    obs.inc("guard.quarantined", n_bad)
    obs.inc("guard.quarantined_batches")
    clean = BatchUpdate(del_src=d_s[~d_bad], del_dst=d_d[~d_bad],
                        ins_src=i_s[~i_bad], ins_dst=i_d[~i_bad])
    return clean, report
