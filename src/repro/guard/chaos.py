"""Deterministic fault injection (ISSUE 9 tentpole, piece 4).

A recovery path that is never exercised is a recovery path that does not
work. ``ChaosMonkey`` is a seeded injector producing every fault class the
guard layer claims to survive, used by ``tests/test_guard.py`` and
``benchmarks/bench_guard.py``:

  * ``corrupt_batch``    — splice out-of-range ids (negative and ≥ n) or a
                           duplicate flood into a valid ``BatchUpdate``
                           (exercises validate/quarantine);
  * ``poison_ranks``     — NaN-poison or bit-flip random lanes of a rank
                           vector (exercises the H_NONFINITE / H_MASS_DRIFT
                           watchdog bits and the escalation ladder);
  * ``force_nonconvergence`` — cap a session's per-batch solve budget at
                           ``max_iter=1`` (exercises H_MAX_ITER and the
                           recovery-params rungs);
  * ``truncate_journal`` — tear the journal file mid-record, as a crash
                           during ``append`` would (exercises ``scan``'s
                           longest-valid-prefix replay).

Everything is driven by one ``numpy`` Generator seeded at construction, so
a failing chaos test reproduces exactly.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.graph import BatchUpdate

__all__ = ["ChaosMonkey"]


class ChaosMonkey:
    """Seeded fault injector for guard tests and benches."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    # -- delta corruption ----------------------------------------------------

    def corrupt_batch(self, batch: BatchUpdate, n: int,
                      mode: str = "out_of_range", k: int = 4
                      ) -> BatchUpdate:
        """Return a corrupted copy of ``batch``.

        ``out_of_range``: append ``k`` insertion pairs whose ids alias other
        edges' keys under ``src*n + dst`` (negative, == n, and far beyond n —
        the exact ids that used to corrupt ``edge_keys`` silently).
        ``dup_flood``: append one valid insertion pair repeated ``k`` times
        (must coalesce to a single edge, never multiply mass).
        """
        i_s = np.asarray(batch.ins_src, np.int64)
        i_d = np.asarray(batch.ins_dst, np.int64)
        if mode == "out_of_range":
            bad_s = self.rng.integers(0, n, size=k)
            bad_d = np.asarray(
                [n, -1, n + int(self.rng.integers(1, n)), -n])[:k]
            self.rng.shuffle(bad_d)
            i_s = np.concatenate([i_s, bad_s])
            i_d = np.concatenate([i_d, bad_d])
        elif mode == "dup_flood":
            u = int(self.rng.integers(0, n))
            v = int(self.rng.integers(0, n))
            i_s = np.concatenate([i_s, np.full(k, u, np.int64)])
            i_d = np.concatenate([i_d, np.full(k, v, np.int64)])
        else:
            raise ValueError(f"unknown corruption mode: {mode!r}")
        return BatchUpdate(del_src=np.asarray(batch.del_src, np.int64),
                           del_dst=np.asarray(batch.del_dst, np.int64),
                           ins_src=i_s, ins_dst=i_d)

    # -- rank poisoning ------------------------------------------------------

    def poison_ranks(self, ranks, mode: str = "nan", k: int = 1, idx=None):
        """Return a poisoned copy of a rank vector (any shape).

        ``nan`` writes NaN into ``k`` random lanes; ``bitflip`` flips one
        random sign/exponent bit of ``k`` random lanes' float64 payload (may
        stay finite — that is the point: the mass-drift bit must catch it).
        ``idx`` pins the poisoned lanes (deterministic tests that need the
        corruption OUTSIDE the batch frontier: a lane the solve sweeps gets
        recomputed from its neighbors, i.e. PageRank self-heals it — only a
        frozen unaffected lane carries corruption through, which is exactly
        the case the mass-drift watchdog exists for).
        """
        r = np.array(ranks, copy=True)
        flat = r.reshape(-1)
        if idx is None:
            idx = self.rng.choice(flat.size, size=min(k, flat.size),
                                  replace=False)
        else:
            idx = np.asarray(idx, np.int64)
        if mode == "nan":
            flat[idx] = np.nan
        elif mode == "bitflip":
            bits = flat[idx].view(np.uint64)
            # sign/exponent bits only, so the flip is consequential
            shift = self.rng.integers(52, 64, size=idx.size)
            flat[idx] = (bits ^ (np.uint64(1) << shift.astype(np.uint64))
                         ).view(np.float64)
        else:
            raise ValueError(f"unknown poison mode: {mode!r}")
        return jnp.asarray(r)

    # -- solve-budget starvation --------------------------------------------

    def force_nonconvergence(self, session) -> None:
        """Cap the session's per-batch solve at one iteration. Recovery must
        come from the guard's ``recovery_params`` rungs, which keep the full
        budget — exactly the degraded-serving shape of FrogWild!-style
        bounded-error PageRank."""
        session.params = session.params._replace(max_iter=1)

    # -- journal tearing -----------------------------------------------------

    def truncate_journal(self, path: str,
                         nbytes: Optional[int] = None) -> int:
        """Truncate the journal to ``nbytes`` (default: a random cut inside
        the final quarter — mid-record with high probability). Returns the
        new size."""
        size = os.path.getsize(path)
        if nbytes is None:
            lo = max(1, (3 * size) // 4)
            nbytes = int(self.rng.integers(lo, size))
        with open(path, "r+b") as f:
            f.truncate(nbytes)
        return nbytes
