"""Numerical-health word: device-side solve diagnostics (ISSUE 9 tentpole).

Every solve loop in the repo converges on the same two scalars — the L∞
rank delta of the last sweep and the iteration counter — and the final rank
vector is already resident when the loop exits. The health word packs the
three failure modes a chained DF-P stream must distinguish from success
into one int32 bitmask computed from exactly those values:

  ``H_MAX_ITER``   the loop exited at ``max_iter`` with the L∞ delta still
                   above τ — "ran out of iterations", which the legacy
                   ``(r, iters)`` return made indistinguishable from
                   convergence;
  ``H_NONFINITE``  NaN/Inf reached the ranks. No extra HBM pass is needed:
                   a non-finite rank propagates into the sweep's L∞ |Δr|
                   reduction (``max`` propagates NaN; an unaffected
                   poisoned lane yields ``|NaN - NaN| = NaN`` too), and the
                   rank-mass sum catches anything the delta misses;
  ``H_MASS_DRIFT`` Σ R drifted from 1 beyond ``mass_tol`` — the cheap
                   whole-vector invariant of PageRank (teleport + pull
                   conserve probability mass), which catches silent
                   bit-level corruption that stays finite.

The word is computed INSIDE the jitted drivers (one fused reduction over
the final ranks for the mass term — once per solve, not per iteration) and
returned as a device scalar; callers that never look at it pay nothing but
that reduction. ``NaN > τ`` is False, so a poisoned solve exits its while
loop on the first NaN sweep rather than spinning to ``max_iter`` — the
watchdog fires after one iteration, not 500.

This module is import-light on purpose (jax only): the core engines import
it as a submodule (``from ..guard.health import ...``) without touching
``repro.guard.__init__``, keeping guard <-> core import cycles impossible.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["HEALTH_OK", "H_MAX_ITER", "H_NONFINITE", "H_MASS_DRIFT",
           "MASS_TOL", "health_word", "rank_mass", "health_flags",
           "describe_health"]

HEALTH_OK = 0
H_MAX_ITER = 1 << 0     # exited at max_iter, delta still > tau
H_NONFINITE = 1 << 1    # NaN/Inf in the final delta or rank mass
H_MASS_DRIFT = 1 << 2   # |sum(R) - 1| > mass_tol

#: default rank-mass tolerance. DF/DF-P are *approximate* by design: an
#: unaffected vertex keeps its previous-graph rank, so a healthy chained
#: solve legitimately drifts Σ R by O(τ_f · |frontier boundary|) — measured
#: ~3e-6 on small graphs with the default τ_f = 1e-6. The default sits two
#: decades above τ_f (never flags the paper's approximation) and well below
#: real corruption: the smallest exponent-bit flip doubles one rank,
#: moving Σ R by ~1/(2n).
MASS_TOL = 1e-4

_FLAG_NAMES = ((H_MAX_ITER, "max_iter"), (H_NONFINITE, "nonfinite"),
               (H_MASS_DRIFT, "mass_drift"))


def health_word(delta: jnp.ndarray, iters: jnp.ndarray, mass: jnp.ndarray,
                *, tau: float, max_iter: int,
                mass_tol: float = MASS_TOL) -> jnp.ndarray:
    """Pack the post-loop scalars into the int32 health bitmask.

    ``delta`` is the final L∞ |Δr| the loop converged on (its while-cond
    scalar), ``iters`` the iteration count, ``mass`` the Σ R of the final
    ranks (callers on sharded layouts pass the psum of their valid-masked
    local sums). All three are device scalars; so is the result.
    """
    bad_iter = (iters >= max_iter) & (delta > tau)
    nonfinite = ~(jnp.isfinite(delta) & jnp.isfinite(mass))
    drift = jnp.abs(mass - 1.0) > mass_tol
    return (bad_iter.astype(jnp.int32) * H_MAX_ITER
            | nonfinite.astype(jnp.int32) * H_NONFINITE
            | drift.astype(jnp.int32) * H_MASS_DRIFT)


def rank_mass(r: jnp.ndarray, valid: Optional[jnp.ndarray] = None
              ) -> jnp.ndarray:
    """Σ R over real vertices (``valid`` masks a padded sharded slice)."""
    if valid is not None:
        r = jnp.where(valid, r, 0)
    return jnp.sum(r)


def health_flags(word: int) -> tuple:
    """Decode a host-side word into its flag names, e.g. ('max_iter',)."""
    return tuple(name for bit, name in _FLAG_NAMES if int(word) & bit)


def describe_health(word: int) -> str:
    """Human-readable form: 'ok' or '+'-joined flag names."""
    return "+".join(health_flags(word)) or "ok"
