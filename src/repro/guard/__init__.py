"""repro.guard — fault-tolerant streaming sessions (ISSUE 9).

The paper's DF-P protocol assumes clean batch streams and convergent
chained solves; a production stream session must survive malformed deltas,
numerically-poisoned or non-converging solves, and process crashes. This
package wraps the streaming lifecycle in four pieces (DESIGN.md §13):

  * ``validate``  — strict ingest validation with a raise-vs-quarantine
    policy knob (out-of-range ids used to silently corrupt ``edge_keys``);
  * ``health``    — a device-side health word every solve can return
    (converged-at-max_iter, NaN/Inf, rank-mass drift), consumed by the
    session's escalation ladder (compact → dense DF-P → static resync);
  * ``journal``   — write-ahead delta journal + atomic session checkpoints;
    ``StreamSession.restore(dir)`` replays to bit-identical state;
  * ``chaos``     — seeded fault injector (corrupt deltas, NaN/bit-flip
    poisoning, forced non-convergence, torn journals) for tests/benches.

``GuardConfig`` is the one knob object the session takes; ``guard=None``
keeps the legacy fully-ungated behavior (the overhead baseline
``benchmarks/bench_guard.py`` measures against).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .validate import (POLICIES, QuarantineReport, ValidationError,
                       validate_batch)
from .health import (HEALTH_OK, H_MASS_DRIFT, H_MAX_ITER, H_NONFINITE,
                     MASS_TOL, describe_health, health_flags, health_word,
                     rank_mass)
from .journal import (DeltaJournal, JournalRecord, journal_path,
                      load_session_checkpoint, save_session_checkpoint)
from .chaos import ChaosMonkey

__all__ = [
    "GuardConfig",
    "POLICIES", "QuarantineReport", "ValidationError", "validate_batch",
    "HEALTH_OK", "H_MAX_ITER", "H_NONFINITE", "H_MASS_DRIFT", "MASS_TOL",
    "health_word", "rank_mass", "health_flags", "describe_health",
    "DeltaJournal", "JournalRecord", "journal_path",
    "save_session_checkpoint", "load_session_checkpoint",
    "ChaosMonkey",
]


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Fault-tolerance knobs for a ``StreamSession`` (DESIGN.md §13).

    With a ``GuardConfig`` attached the session (a) applies the ingest
    ``policy`` to every raw batch, (b) asks every solve for its health word
    and walks the escalation ladder on any set bit, and (c) optionally
    audits chained drift against ``static_reference()`` every
    ``audit_every`` batches, resyncing when it exceeds ``audit_tol``.
    """
    #: ingest id-range policy: "raise" (strict) or "quarantine"
    policy: str = "raise"
    #: |Σ R - 1| tolerance for the H_MASS_DRIFT health bit
    mass_tol: float = MASS_TOL
    #: max escalation rungs attempted per batch (2 = retry + resync)
    retry_budget: int = 2
    #: run a drift audit every K applied batches (0 = never)
    audit_every: int = 0
    #: L1(chained, static_reference) threshold that triggers auto-resync
    audit_tol: float = 1e-8
    #: solve params for the recovery rungs; None = the session's params
    #: with the full default iteration budget restored (so a chaos-starved
    #: ``max_iter=1`` session still recovers with a real solve)
    recovery_params: Optional[object] = None
    #: where escalation-exhaustion post-mortem bundles land (DESIGN.md §14);
    #: None falls back to the session's journal_dir, then
    #: ``$REPRO_POSTMORTEM_DIR`` (unset: no bundle is written)
    postmortem_dir: Optional[str] = None

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown guard policy: {self.policy!r}")
