"""Delta journal + session checkpoints (ISSUE 9 tentpole, piece 3).

Crash recovery for a streaming session is two files' worth of state:

  * an **append-only journal** of every canonical Δ^t, written *before* the
    delta touches the snapshot (write-ahead). Records are length-prefixed
    and CRC-protected; ``scan`` replays the longest valid prefix and flags
    a torn tail (a crash mid-``append`` loses at most the record being
    written, never an earlier one);
  * periodic **checkpoints** of the full session state (ranks + the
    snapshot's host mirrors), written through ``train/checkpoint.py``'s
    atomic-manifest save/restore primitives — a crash mid-checkpoint never
    corrupts the previous one.

``StreamSession.restore(dir)`` = load the newest checkpoint, then replay
every journaled delta with a later sequence number. Because the checkpoint
captures the snapshot mirrors *exactly* (including free-list order, which
steers future slot placement and therefore floating-point summation order),
the restored session is bit-identical to one that never crashed
(DESIGN.md §13).

This module deliberately imports nothing from ``repro.stream`` — records
are plain (seq, n, arrays) tuples and checkpoints are flat dicts of numpy
arrays, so guard <-> stream import cycles cannot form; the session owns the
translation to/from ``Delta``.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import zlib
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from ..obs.spans import get_registry as _obs

__all__ = ["JournalRecord", "DeltaJournal", "journal_path",
           "save_session_checkpoint", "load_session_checkpoint"]

#: record header: magic, seq (batch index), n, n_del, n_ins, payload crc32
_MAGIC = 0x4C445247  # "GRDL"
_HEADER = struct.Struct("<IQQIII")
JOURNAL_NAME = "deltas.journal"


def journal_path(directory: str) -> str:
    return os.path.join(directory, JOURNAL_NAME)


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One journaled canonical Δ^t (arrays int32, unique/disjoint pairs)."""
    seq: int
    n: int
    del_src: np.ndarray
    del_dst: np.ndarray
    ins_src: np.ndarray
    ins_dst: np.ndarray


def _payload(rec: JournalRecord) -> bytes:
    return b"".join(np.ascontiguousarray(a, dtype="<i4").tobytes()
                    for a in (rec.del_src, rec.del_dst,
                              rec.ins_src, rec.ins_dst))


class DeltaJournal:
    """Append-only, CRC-checked delta log. One writer, any-time readers."""

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self._fsync = fsync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")

    def append(self, rec: JournalRecord) -> None:
        payload = _payload(rec)
        head = _HEADER.pack(_MAGIC, rec.seq, rec.n,
                            int(rec.del_src.shape[0]),
                            int(rec.ins_src.shape[0]),
                            zlib.crc32(payload) & 0xFFFFFFFF)
        self._f.write(head + payload)
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())
        obs = _obs()
        obs.inc("guard.journal.appends")
        obs.inc("guard.journal.bytes", len(head) + len(payload))

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def scan(path: str) -> Tuple[List[JournalRecord], bool]:
        """Read the longest valid record prefix.

        Returns ``(records, truncated)`` — ``truncated`` is True when the
        file ends in a torn/corrupt record (short header, short payload,
        bad magic or CRC mismatch), which bumps ``guard.journal.truncated``.
        Everything before the tear is intact by construction (records are
        written in one buffered write each, in order).
        """
        records: List[JournalRecord] = []
        truncated = False
        if not os.path.exists(path):
            return records, truncated
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        while off < len(data):
            if off + _HEADER.size > len(data):
                truncated = True
                break
            magic, seq, n, n_del, n_ins, crc = _HEADER.unpack_from(data, off)
            body = 4 * (2 * n_del + 2 * n_ins)
            if magic != _MAGIC or off + _HEADER.size + body > len(data):
                truncated = True
                break
            payload = data[off + _HEADER.size: off + _HEADER.size + body]
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                truncated = True
                break
            arrs = np.frombuffer(payload, dtype="<i4")
            d_s, d_d, i_s, i_d = np.split(
                arrs, [n_del, 2 * n_del, 2 * n_del + n_ins])
            records.append(JournalRecord(
                seq=int(seq), n=int(n),
                del_src=d_s.astype(np.int32), del_dst=d_d.astype(np.int32),
                ins_src=i_s.astype(np.int32), ins_dst=i_d.astype(np.int32)))
            off += _HEADER.size + body
        if truncated:
            _obs().inc("guard.journal.truncated")
        return records, truncated


# ---------------------------------------------------------------------------
# Session checkpoints: flat {name: array} dicts through train/checkpoint.py
# ---------------------------------------------------------------------------

def save_session_checkpoint(directory: str, step: int, arrays: dict,
                            extra: Optional[dict] = None) -> str:
    """Atomic checkpoint of a flat ``{name: np.ndarray}`` dict.

    ``step`` is the batch sequence number the state is valid *after*;
    ``extra`` must be JSON-serializable (session config, capacity plans).
    """
    from ..train.checkpoint import save_checkpoint  # lazy: keeps guard
    # importable without pulling the training stack in at module load
    assert all(isinstance(k, str) for k in arrays)
    extra = dict(extra or {})
    extra["leaf_keys"] = sorted(arrays)  # tree_flatten's dict-key order
    path = save_checkpoint(directory, step, arrays, extra=extra)
    _obs().inc("guard.checkpoint.saves")
    return path


def load_session_checkpoint(directory: str, step: Optional[int] = None
                            ) -> Tuple[dict, dict, int]:
    """Inverse of ``save_session_checkpoint`` without needing a template:
    the manifest's shapes/dtypes build the ``like`` pytree. Returns
    ``({name: np.ndarray}, extra, step)``; checksums are verified.
    """
    import json
    from ..train.checkpoint import latest_step, restore_checkpoint
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    keys = manifest["extra"]["leaf_keys"]
    like = {}
    for i, key in enumerate(keys):
        meta = manifest["files"][f"leaf_{i:05d}.npy"]
        like[key] = jax.ShapeDtypeStruct(tuple(meta["shape"]),
                                         np.dtype(meta["dtype"]))
    tree, extra, step = restore_checkpoint(directory, like, step=step)
    # np.array (not asarray): the loader may hand back read-only buffers,
    # and restored mirrors must stay editable in place
    arrays = {k: np.array(v) for k, v in tree.items()}
    return arrays, extra, step
