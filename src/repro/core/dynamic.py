"""Dynamic PageRank drivers: ND, DT, DF, DF-P (paper Alg. 2).

All five approaches share `update_ranks` (paper Alg. 3) and the convergence
loop shape of Alg. 1; they differ only in (a) rank initialization, (b) the
affected mask, and (c) frontier expansion/pruning — exactly the paper's
decomposition. Every driver is a single jitted `lax.while_loop`.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import (FS_ACTIVE_ROWS, FS_ACTIVE_TILES, FS_COMPACT,
                       FS_EXPAND_WORK, FS_ITERS, FS_NB, FS_OVERFLOW, FS_PULL,
                       FS_PUSH, active_frontier, expand_affected,
                       expand_frontier, fstats_init, initial_affected,
                       publish_fstats, reach_affected, update_ranks_active)
from .pagerank import DeviceGraph, PRParams, as_device_graph, update_ranks
from ..guard.health import MASS_TOL, health_word, rank_mass
from ..obs.spans import get_registry
from ..obs.trace import trace_init, trace_record

__all__ = ["DeviceBatch", "batch_to_device", "nd_pagerank", "dt_pagerank",
           "df_pagerank", "dfp_pagerank"]


class DeviceBatch(NamedTuple):
    """Batch update staged on device, padded with id == n ("drop" scatters)."""
    del_src: jnp.ndarray
    del_dst: jnp.ndarray
    ins_src: jnp.ndarray
    ins_dst: jnp.ndarray


def batch_to_device(batch, n: int, pad_to: int | None = None) -> DeviceBatch:
    def pad(a, cap):
        a = np.asarray(a, np.int32)
        if cap is None or a.shape[0] == cap:
            return jnp.asarray(a)
        out = np.full(cap, n, np.int32)
        out[:a.shape[0]] = a
        return jnp.asarray(out)
    return DeviceBatch(pad(batch.del_src, pad_to), pad(batch.del_dst, pad_to),
                       pad(batch.ins_src, pad_to), pad(batch.ins_dst, pad_to))


def solve_health(delta, iters, mass, params: PRParams,
                 mass_tol: float = MASS_TOL):
    """Health word of a finished solve loop (guard.health), from the final
    L∞ delta / iteration count / rank mass. A +inf delta is a *signal*
    (compact-engine overflow, distributed delta_every skip), not a number —
    clamp it finite so it reads as H_MAX_ITER, not H_NONFINITE; NaN (real
    poisoning) passes through untouched."""
    dt = jnp.asarray(delta).dtype
    delta = jnp.where(jnp.isposinf(delta), jnp.finfo(dt).max, delta)
    return health_word(delta, iters, mass, tau=params.tau,
                       max_iter=params.max_iter, mass_tol=mass_tol)


def _loop(dg: DeviceGraph, r0: jnp.ndarray, dv0: jnp.ndarray,
          dn0: jnp.ndarray, params: PRParams, *, expand: bool, prune: bool,
          closed_form: bool, pull_sum_fn=None, tb=None, i_off=0,
          fwd=None, caps=None, fs0=None, health: bool = False,
          mass_tol: float = MASS_TOL):
    """Shared Alg. 2 loop. When `expand` is False the affected set is frozen
    (ND/DT); δ_N is then never produced (track_frontier=False).

    `caps` (core.frontier.FrontierCaps, static) switches on the compacted
    execution path: each iteration compacts δ_V into active gather lists and
    runs `update_ranks_active` (edge work O(frontier·degree)); a truncated
    list falls back to the dense full sweep *for that iteration only*
    (lax.cond — no exit, no recompile). With `fwd` (the forward hybrid
    layout) expansion goes push-style through the compacted δ_N worklist
    instead of the dense pull, same per-iteration fallback. Frontier-size
    reductions feed only the device-side `fs` accumulator (returned last)
    and the optional trace buffer — the untraced, uncompacted hot loop
    computes no dense reductions beyond the L∞ it converges on.

    `tb` (obs.trace.TraceBuffer) switches on iteration telemetry: per-sweep
    L∞, frontier size, δ_N and pruned counts recorded at `i_off + i` — the
    offset lets the compact engine's dense fallback append to the buffer its
    compact phase started. The rank math never reads the trace."""

    def body(state):
        r, dv, dn, _, i, tb_, fs = state
        if expand:
            # paper line 16: expansion of the *previous* iteration's frontier,
            # performed only because convergence was not reached (cond passed).
            if caps is not None and fwd is not None:
                dv, est = jax.lax.cond(
                    i > 0,
                    lambda: expand_frontier(dg, fwd, dv, dn, caps),
                    lambda: (dv, jnp.zeros((3,), jnp.int32)))
                fs = fs.at[FS_EXPAND_WORK].add(est[0]) \
                       .at[FS_PUSH].add(est[1]).at[FS_PULL].add(est[2])
            else:
                dv = jax.lax.cond(i > 0,
                                  lambda: expand_affected(dg, dv, dn),
                                  lambda: dv)
        if caps is not None:
            af = active_frontier(dg.buckets, dg.hi_ids, dg.hi_rowmap, dv,
                                 caps)
            kw = dict(alpha=params.alpha, tau_f=params.tau_f,
                      tau_p=params.tau_p, prune=prune,
                      closed_form=closed_form, track_frontier=expand)
            r_new, dv_new, dn_new, delta = jax.lax.cond(
                af.overflow,
                lambda: update_ranks(dg, r, dv, pull_sum_fn=pull_sum_fn,
                                     **kw),
                lambda: update_ranks_active(dg, r, dv, af, **kw))
            ok = (~af.overflow).astype(jnp.int32)
            fs = fs.at[FS_ITERS].add(1).at[FS_COMPACT].add(ok) \
                   .at[FS_OVERFLOW].add(1 - ok) \
                   .at[FS_ACTIVE_ROWS].add(af.n_rows * ok) \
                   .at[FS_ACTIVE_TILES].add(af.n_tiles * ok)
            if len(dg.buckets):
                fs = fs.at[FS_NB:].add(af.bucket_counts * ok)
        else:
            r_new, dv_new, dn_new, delta = update_ranks(
                dg, r, dv, alpha=params.alpha, tau_f=params.tau_f,
                tau_p=params.tau_p, prune=prune, closed_form=closed_form,
                track_frontier=expand, pull_sum_fn=pull_sum_fn)
        if tb is not None:
            frontier = jnp.sum(dv)
            pruned = frontier - jnp.sum(dv_new) if prune else 0
            tb_ = trace_record(tb_, i_off + i, linf=delta, frontier=frontier,
                               delta_n=jnp.sum(dn_new) if expand else 0,
                               pruned=pruned)
        return r_new, dv_new, dn_new, delta, i + 1, tb_, fs

    def cond(state):
        delta, i = state[3], state[4]
        return (delta > params.tau) & (i < params.max_iter)

    fs_init = fs0 if fs0 is not None else fstats_init(len(dg.buckets))
    init = (r0, dv0, dn0, jnp.asarray(jnp.inf, r0.dtype),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(0, jnp.int32) if tb is None else tb, fs_init)
    r, _, _, delta, iters, tb_out, fs = jax.lax.while_loop(cond, body, init)
    # output shape contract: (r, iters)[, tb][, health][, fs-last] — fs
    # stays last so `_publish` can pop it blind; health (guard.health word,
    # one fused Σ R reduction over the final ranks) rides just before it.
    out = [r, iters]
    if tb is not None:
        out.append(tb_out)
    if health:
        # iters vs params.max_iter, NOT i_off+iters: a dense finish runs
        # with the *remaining* budget, so its own exhaustion is exactly the
        # total budget's exhaustion
        out.append(solve_health(delta, iters, rank_mass(r), params,
                                mass_tol))
    if caps is not None:
        out.append(fs)
    return tuple(out)


def nd_pagerank(dg, r_prev: jnp.ndarray, params: PRParams = PRParams(),
                pull_sum_fn=None, trace: bool = False, health: bool = False):
    """Naive-dynamic: previous ranks as the initial guess, all vertices on.

    All four dynamic drivers accept a DeviceGraph or a pre-staged snapshot
    (anything with a `.dg` attribute, e.g. repro.stream.DeviceSnapshot),
    and a ``trace=True`` flag returning (r, iters, obs.trace.TraceBuffer)
    with identical ranks/iters to the untraced call. ``health=True``
    additionally appends the solve's guard.health word (int32 bitmask,
    device-side) after the trace buffer.

    Every driver dispatches under an annotated ``solve.<engine>`` span, so
    its kernels land on the device timeline whenever a profiler trace is
    live (ISSUE 10; the span times host dispatch only).
    """
    with get_registry().span("solve.nd", annotate=True):
        return _nd_pagerank(as_device_graph(dg), r_prev, params, pull_sum_fn,
                            trace, health)


@functools.partial(jax.jit, static_argnames=("params", "pull_sum_fn",
                                             "trace", "health"))
def _nd_pagerank(dg: DeviceGraph, r_prev: jnp.ndarray,
                 params: PRParams = PRParams(), pull_sum_fn=None,
                 trace: bool = False, health: bool = False):
    n = dg.n
    on = jnp.ones((n,), jnp.bool_)
    off = jnp.zeros((n,), jnp.bool_)
    tb = trace_init(params.max_iter, r_prev.dtype, "nd") if trace else None
    return _loop(dg, r_prev, on, off, params, expand=False, prune=False,
                 closed_form=False, pull_sum_fn=pull_sum_fn, tb=tb,
                 health=health)


def dt_pagerank(dg, dg_prev, r_prev: jnp.ndarray, batch: DeviceBatch,
                params: PRParams = PRParams(), pull_sum_fn=None,
                trace: bool = False, health: bool = False):
    """Dynamic Traversal (Desikan et al.): mark everything reachable from the
    updated vertices in G^{t-1} ∪ G^t, then iterate on that frozen set."""
    with get_registry().span("solve.dt", annotate=True):
        return _dt_pagerank(as_device_graph(dg), as_device_graph(dg_prev),
                            r_prev, batch, params, pull_sum_fn, trace, health)


@functools.partial(jax.jit, static_argnames=("params", "pull_sum_fn",
                                             "trace", "health"))
def _dt_pagerank(dg: DeviceGraph, dg_prev: DeviceGraph, r_prev: jnp.ndarray,
                 batch: DeviceBatch, params: PRParams = PRParams(),
                 pull_sum_fn=None, trace: bool = False,
                 health: bool = False):
    n = dg.n
    seeds = jnp.zeros((n,), jnp.bool_)
    seeds = seeds.at[batch.del_src].set(True, mode="drop")
    seeds = seeds.at[batch.del_dst].set(True, mode="drop")
    seeds = seeds.at[batch.ins_src].set(True, mode="drop")
    seeds = seeds.at[batch.ins_dst].set(True, mode="drop")
    affected = reach_affected(dg, seeds) | reach_affected(dg_prev, seeds)
    off = jnp.zeros((n,), jnp.bool_)
    tb = trace_init(params.max_iter, r_prev.dtype, "dt") if trace else None
    return _loop(dg, r_prev, affected, off, params, expand=False, prune=False,
                 closed_form=False, pull_sum_fn=pull_sum_fn, tb=tb,
                 health=health)


def _df_like(dg: DeviceGraph, r_prev: jnp.ndarray, batch: DeviceBatch,
             params: PRParams, *, prune: bool, pull_sum_fn=None,
             trace: bool = False, fwd=None, caps=None,
             health: bool = False):
    n = dg.n
    dv, dn = initial_affected(n, batch.del_src, batch.del_dst, batch.ins_src)
    fs0 = None
    if caps is not None:
        # this Python body runs only when the jitted driver (re)traces —
        # the counter is the recompile telemetry the streamed-session
        # zero-recompile acceptance reads (bench_frontier.py)
        get_registry().inc("frontier.retrace")
        fs0 = fstats_init(len(dg.buckets))
    if caps is not None and fwd is not None:
        # paper line 9: initial expansion, via the compacted out-edge walk
        dv, est = expand_frontier(dg, fwd, dv, dn, caps)
        fs0 = fs0.at[FS_EXPAND_WORK].add(est[0]) \
                 .at[FS_PUSH].add(est[1]).at[FS_PULL].add(est[2])
    else:
        dv = expand_affected(dg, dv, dn)  # paper line 9: initial expansion
    dn0 = jnp.zeros((n,), jnp.bool_)
    tb = trace_init(params.max_iter, r_prev.dtype,
                    "dfp" if prune else "df") if trace else None
    return _loop(dg, r_prev, dv, dn0, params, expand=True, prune=prune,
                 closed_form=prune, pull_sum_fn=pull_sum_fn, tb=tb,
                 fwd=fwd, caps=caps, fs0=fs0, health=health)


def _resolve_frontier(dg, fwd, frontier_caps):
    """(fwd DeviceGraph|None, caps) for the compacted path. Snapshots carry
    their own forward layout (`.fwd_dg`); with caps but no forward layout
    the loop still compacts the rank pull and keeps the dense expansion."""
    if frontier_caps is None:
        return None, None
    if fwd is None:
        fwd = getattr(dg, "fwd_dg", None)
    return (as_device_graph(fwd) if fwd is not None else None), frontier_caps


def _publish(out, caps, trace):
    """Pop the fstats vector off a compacted driver's output, fold it into
    the host registry, and return the legacy (r, iters[, tb]) shape."""
    if caps is None:
        return out
    *rest, fs = out
    publish_fstats(fs)
    return tuple(rest)


def df_pagerank(dg, r_prev: jnp.ndarray, batch: DeviceBatch,
                params: PRParams = PRParams(), pull_sum_fn=None,
                trace: bool = False, fwd=None, frontier_caps=None,
                health: bool = False):
    """Dynamic Frontier: incremental expansion, no pruning (Eq. 1 update).

    `frontier_caps` (core.frontier.FrontierCaps / caps_for) switches on the
    compacted execution path — active gather lists + push expansion, full
    sweep only on capacity overflow; identical results either way."""
    fwdd, caps = _resolve_frontier(dg, fwd, frontier_caps)
    with get_registry().span("solve.df", annotate=True):
        out = _df_pagerank(as_device_graph(dg), fwdd, r_prev, batch, params,
                           pull_sum_fn, trace, caps, health)
    return _publish(out, caps, trace)


@functools.partial(jax.jit, static_argnames=("params", "pull_sum_fn",
                                             "trace", "caps", "health"))
def _df_pagerank(dg: DeviceGraph, fwd, r_prev: jnp.ndarray,
                 batch: DeviceBatch, params: PRParams = PRParams(),
                 pull_sum_fn=None, trace: bool = False, caps=None,
                 health: bool = False):
    return _df_like(dg, r_prev, batch, params, prune=False,
                    pull_sum_fn=pull_sum_fn, trace=trace, fwd=fwd, caps=caps,
                    health=health)


def dfp_pagerank(dg, r_prev: jnp.ndarray, batch: DeviceBatch,
                 params: PRParams = PRParams(), pull_sum_fn=None,
                 trace: bool = False, fwd=None, frontier_caps=None,
                 health: bool = False):
    """Dynamic Frontier with Pruning: expansion + pruning, closed form Eq. 2.

    See `df_pagerank` for the `frontier_caps` compacted path."""
    fwdd, caps = _resolve_frontier(dg, fwd, frontier_caps)
    with get_registry().span("solve.dfp", annotate=True):
        out = _dfp_pagerank(as_device_graph(dg), fwdd, r_prev, batch, params,
                            pull_sum_fn, trace, caps, health)
    return _publish(out, caps, trace)


@functools.partial(jax.jit, static_argnames=("params", "pull_sum_fn",
                                             "trace", "caps", "health"))
def _dfp_pagerank(dg: DeviceGraph, fwd, r_prev: jnp.ndarray,
                  batch: DeviceBatch, params: PRParams = PRParams(),
                  pull_sum_fn=None, trace: bool = False, caps=None,
                  health: bool = False):
    return _df_like(dg, r_prev, batch, params, prune=True,
                    pull_sum_fn=pull_sum_fn, trace=trace, fwd=fwd, caps=caps,
                    health=health)
