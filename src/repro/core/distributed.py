"""Multi-device / multi-pod PageRank via shard_map.

1-D vertex partition over all mesh axes (flattened): every shard owns
``n_loc = n_pad / nd`` vertices — their ELL rows, tile-padded CSR slices,
ranks and affected flags. The pull model makes the per-iteration communication
exactly one collective: ``all_gather`` of the contribution vector
``c = R / outdeg`` (V·4 B), plus a scalar ``pmax`` for convergence — this is
the paper's "one write per vertex" discipline lifted to the cluster level
(each device writes only its own rank slice; no cross-device scatter exists).

For DF-P, the frontier flags δ_N ride the same all-gather (packed as f32
alongside c, one fused collective — see EXPERIMENTS.md §Perf hillclimb).

Elasticity: `build_sharded` is a pure host function of (graph, nd); on device
failure / resize, rebuild with the new nd and re-enter at the checkpointed
(R, δ_V) — see train/elastic.py for the generic machinery.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .graph import Graph, build_hybrid
from .pagerank import PRParams

try:  # JAX >= 0.4.35 spelling
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

__all__ = ["ShardedGraph", "build_sharded", "distributed_static_pagerank",
           "distributed_dfp_pagerank", "pagerank_step_specs"]


class ShardedGraph(NamedTuple):
    """Stacked per-shard hybrid layouts. Leading axis = shard."""
    ell_idx: jnp.ndarray    # [nd, n_loc, d_p] int32, GLOBAL column ids
    ell_mask: jnp.ndarray   # [nd, n_loc, d_p] f32
    hi_pos: jnp.ndarray     # [nd, hi_cap] int32, LOCAL row ids (sentinel n_loc)
    hi_tiles: jnp.ndarray   # [nd, t_cap, tile] int32, GLOBAL column ids
    hi_tmask: jnp.ndarray   # [nd, t_cap, tile] f32
    hi_rowmap: jnp.ndarray  # [nd, t_cap] int32
    out_deg: jnp.ndarray    # [nd, n_loc] int32 (>=1)
    valid: jnp.ndarray      # [nd, n_loc] bool (False on padding vertices)
    n_true: int             # real |V| (for the (1-α)/|V| constant)

    @property
    def nd(self) -> int:
        return self.ell_idx.shape[0]

    @property
    def n_loc(self) -> int:
        return self.ell_idx.shape[1]


def build_sharded(g: Graph, nd: int, d_p: int = 64, tile: int = 1024
                  ) -> ShardedGraph:
    """Host-side partitioner: round-robin-free contiguous vertex blocks.

    Pads |V| to a multiple of nd with isolated self-loop vertices (masked out
    of updates and results). Per-shard hi/tile capacities are maxed across
    shards so stacking gives static shapes (required for jit/shard_map).
    """
    n = g.n
    n_pad = ((n + nd - 1) // nd) * nd
    n_loc = n_pad // nd
    indeg = g.in_degree()
    out_deg = g.out_degree()

    shards = []
    for s in range(nd):
        lo, hi = s * n_loc, min((s + 1) * n_loc, n)
        rows = np.arange(lo, max(lo, hi))
        shards.append(rows)

    # build per-shard ragged pieces first to find caps
    pieces = []
    for rows in shards:
        ell_i = np.zeros((n_loc, d_p), np.int32)
        ell_m = np.zeros((n_loc, d_p), np.float32)
        hi_rows = []
        tiles = []
        tmask = []
        rowmap = []
        for li, v in enumerate(rows):
            s0, s1 = g.t_offsets[v], g.t_offsets[v + 1]
            nbr = g.t_sources[s0:s1]
            if nbr.size <= d_p:
                ell_i[li, :nbr.size] = nbr
                ell_m[li, :nbr.size] = 1.0
            else:
                slot = len(hi_rows)
                hi_rows.append(li)
                nt = (nbr.size + tile - 1) // tile
                pad = nt * tile - nbr.size
                padded = np.concatenate([nbr, np.zeros(pad, np.int32)])
                m = np.concatenate([np.ones(nbr.size, np.float32),
                                    np.zeros(pad, np.float32)])
                tiles.append(padded.reshape(nt, tile))
                tmask.append(m.reshape(nt, tile))
                rowmap.extend([slot] * nt)
        pieces.append((ell_i, ell_m, hi_rows, tiles, tmask, rowmap, rows))

    hi_cap = max(1, max(len(p[2]) for p in pieces))
    t_cap = max(1, max(len(p[5]) for p in pieces))

    ell_idx = np.stack([p[0] for p in pieces])
    ell_mask = np.stack([p[1] for p in pieces])
    hi_pos = np.full((nd, hi_cap), n_loc, np.int32)
    hi_tiles = np.zeros((nd, t_cap, tile), np.int32)
    hi_tmask = np.zeros((nd, t_cap, tile), np.float32)
    hi_rowmap = np.full((nd, t_cap), hi_cap - 1, np.int32)
    deg = np.ones((nd, n_loc), np.int32)
    valid = np.zeros((nd, n_loc), bool)
    for s, (ei, em, hr, ti, tm, rm, rows) in enumerate(pieces):
        if hr:
            hi_pos[s, :len(hr)] = np.asarray(hr, np.int32)
        if rm:
            hi_tiles[s, :len(rm)] = np.concatenate(ti, axis=0)
            hi_tmask[s, :len(rm)] = np.concatenate(tm, axis=0)
            hi_rowmap[s, :len(rm)] = np.asarray(rm, np.int32)
        deg[s, :rows.size] = out_deg[rows]
        valid[s, :rows.size] = True

    return ShardedGraph(
        ell_idx=jnp.asarray(ell_idx), ell_mask=jnp.asarray(ell_mask),
        hi_pos=jnp.asarray(hi_pos), hi_tiles=jnp.asarray(hi_tiles),
        hi_tmask=jnp.asarray(hi_tmask), hi_rowmap=jnp.asarray(hi_rowmap),
        out_deg=jnp.asarray(deg), valid=jnp.asarray(valid), n_true=n)


# ---------------------------------------------------------------------------
# Local (per-shard) pull + update, consuming the gathered contribution vector
# ---------------------------------------------------------------------------

def _local_pull(sg_loc, c_full: jnp.ndarray) -> jnp.ndarray:
    dt = c_full.dtype
    ell_idx, ell_mask = sg_loc["ell_idx"], sg_loc["ell_mask"]
    low = jnp.sum(jnp.take(c_full, ell_idx, axis=0) * ell_mask.astype(dt),
                  axis=1)
    tile_sums = jnp.sum(jnp.take(c_full, sg_loc["hi_tiles"], axis=0)
                        * sg_loc["hi_tmask"].astype(dt), axis=1)
    hi_cap = sg_loc["hi_pos"].shape[0]
    per_slot = jax.ops.segment_sum(tile_sums, sg_loc["hi_rowmap"],
                                   num_segments=hi_cap)
    return low.at[sg_loc["hi_pos"]].add(per_slot, mode="drop")


def _local_pull_max(sg_loc, x_full: jnp.ndarray) -> jnp.ndarray:
    dt = x_full.dtype
    low = jnp.max(jnp.take(x_full, sg_loc["ell_idx"], axis=0)
                  * sg_loc["ell_mask"].astype(dt), axis=1)
    tmax = jnp.max(jnp.take(x_full, sg_loc["hi_tiles"], axis=0)
                   * sg_loc["hi_tmask"].astype(dt), axis=1)
    hi_cap = sg_loc["hi_pos"].shape[0]
    per_slot = jnp.maximum(
        jax.ops.segment_max(tmax, sg_loc["hi_rowmap"], num_segments=hi_cap), 0)
    return jnp.maximum(low, jnp.zeros_like(low).at[sg_loc["hi_pos"]]
                       .max(per_slot, mode="drop"))


_FIELDS = ("ell_idx", "ell_mask", "hi_pos", "hi_tiles", "hi_tmask",
           "hi_rowmap", "out_deg", "valid")


def _as_dict(sg: ShardedGraph) -> dict:
    return {k: getattr(sg, k) for k in _FIELDS}


def _squeeze_shard(sgd: dict) -> dict:
    """Inside shard_map each field has leading dim 1 — drop it."""
    return {k: v[0] for k, v in sgd.items()}


def _make_loop(axis, params: PRParams, n_true: int, *, dfp: bool,
               compact_frontier: bool = False, delta_every: int = 1):
    """Build the per-shard while-loop body. `axis` is the (tuple of) mesh
    axis name(s) the vertex dimension is sharded over. `compact_frontier`
    gathers δ_N as uint8 instead of the rank dtype (§Perf hillclimb #3:
    the frontier all-gather shrinks 4-8x; the pull-max upcasts locally).
    `delta_every=k` evaluates the global L-inf all-reduce every k iterations
    only — the straggler/latency mitigation from DESIGN.md §8: up to k-1
    surplus (cheap, local) iterations traded for k-fold fewer global syncs."""

    def loop(sgd: dict, r0, dv0, dn0):
        sgl = _squeeze_shard(sgd)
        r0, dv0, dn0 = r0[0], dv0[0], dn0[0]
        dt = r0.dtype
        d = sgl["out_deg"].astype(dt)
        valid = sgl["valid"]
        c0 = jnp.asarray((1.0 - params.alpha) / n_true, dt)

        def body(state):
            r, dv, dn, _, i = state
            if dfp:
                gdt = jnp.uint8 if compact_frontier else dt
                dn_full = jax.lax.all_gather(dn.astype(gdt), axis, tiled=True)
                grow = _local_pull_max(sgl, dn_full.astype(dt)) > 0
                dv = jnp.where(i > 0, dv | grow, dv) & valid
            c_loc = r / d
            c_full = jax.lax.all_gather(c_loc, axis, tiled=True)
            s = _local_pull(sgl, c_full)
            if dfp:
                rv = (c0 + params.alpha * (s - r / d)) / (1 - params.alpha / d)
            else:
                rv = c0 + params.alpha * s
            aff = dv & valid
            r_new = jnp.where(aff, rv, r)
            dr = jnp.abs(r_new - r)
            rel = dr / jnp.maximum(r_new, r)
            if dfp:
                dv = aff & ~(rel <= params.tau_p)
                dn_new = rel > params.tau_f
            else:
                dv = aff
                dn_new = dn
            local = jnp.max(dr)
            if delta_every > 1:
                check = (i + 1) % delta_every == 0
                delta = jnp.where(check, jax.lax.pmax(local, axis),
                                  jnp.asarray(jnp.inf, dt))
                delta = jnp.where(check, delta, jnp.asarray(jnp.inf, dt))
            else:
                delta = jax.lax.pmax(local, axis)
            return r_new, dv, dn_new, delta, i + 1

        def cond(state):
            *_, delta, i = state
            return (delta > params.tau) & (i < params.max_iter)

        init = (r0, dv0, dn0, jnp.asarray(jnp.inf, dt),
                jnp.asarray(0, jnp.int32))
        r, dv, dn, _, iters = jax.lax.while_loop(cond, body, init)
        return r[None], iters

    return loop


def _specs(mesh: Mesh):
    axis = tuple(mesh.axis_names)
    shard = P(axis)
    return axis, shard


def pagerank_step_specs(mesh: Mesh):
    """(in_specs, out_specs) used by the dry-run lowering for this workload."""
    axis, shard = _specs(mesh)
    return shard, axis


def distributed_static_pagerank(mesh: Mesh, sg: ShardedGraph, r0: jnp.ndarray,
                                params: PRParams = PRParams(),
                                delta_every: int = 1):
    """r0: [nd, n_loc] stacked ranks. Returns (ranks [nd, n_loc], iters)."""
    axis, shard = _specs(mesh)
    nd, n_loc = sg.out_deg.shape
    on = jnp.ones((nd, n_loc), jnp.bool_)
    off = jnp.zeros((nd, n_loc), jnp.bool_)
    loop = _make_loop(axis, params, sg.n_true, dfp=False,
                      delta_every=delta_every)
    fn = _shard_map(loop, mesh=mesh,
                    in_specs=({k: shard for k in _FIELDS}, shard, shard, shard),
                    out_specs=(shard, P()))
    return jax.jit(fn)(_as_dict(sg), r0, on, off)


def distributed_dfp_pagerank(mesh: Mesh, sg: ShardedGraph, r_prev: jnp.ndarray,
                             dv0: jnp.ndarray, dn0: jnp.ndarray,
                             params: PRParams = PRParams()):
    """DF-P on the cluster: dv0/dn0 are the initial affected / to-expand flags
    ([nd, n_loc], from frontier.initial_affected sharded by the host)."""
    axis, shard = _specs(mesh)
    loop = _make_loop(axis, params, sg.n_true, dfp=True)
    fn = _shard_map(loop, mesh=mesh,
                    in_specs=({k: shard for k in _FIELDS}, shard, shard, shard),
                    out_specs=(shard, P()))
    return jax.jit(fn)(_as_dict(sg), r_prev, dv0, dn0)
