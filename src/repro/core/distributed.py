"""Multi-device / multi-pod PageRank via shard_map.

1-D vertex partition over all mesh axes (flattened): every shard owns
``n_loc = n_pad / nd`` vertices — their ELL rows, tile-padded CSR slices,
ranks and affected flags. The pull model makes the per-iteration communication
exactly one collective: ``all_gather`` of the contribution vector
``c = R / outdeg`` (V·4 B), plus a scalar ``pmax`` for convergence — this is
the paper's "one write per vertex" discipline lifted to the cluster level
(each device writes only its own rank slice; no cross-device scatter exists).

For DF-P, the frontier flags δ_N ride the same all-gather (packed as f32
alongside c, one fused collective — see DESIGN.md §5).

Layout sharing: each shard's block is laid out by the *same* vectorized
`build_hybrid_rows` primitive that builds the single-device hybrid
(DESIGN.md §5) — stored column ids are global, row ids are shard-local —
and the per-iteration math is the *same* `core.rank_step.rank_step` the
dense engine uses; this loop only adds the all-gather plumbing around it.

Elasticity: `build_sharded` is a pure host function of (graph, nd); on device
failure / resize, rebuild with the new nd and re-enter at the checkpointed
(R, δ_V) — see train/elastic.py for the generic machinery. Capacities follow
the pow2/never-shrink discipline of DeviceSnapshot (`sharded_caps`), so
re-sharded snapshots of a dynamic graph keep jit-stable shapes (§7).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .dynamic import solve_health
from .frontier import (FS_ACTIVE_ROWS, FS_ACTIVE_TILES, FS_COMPACT, FS_ITERS,
                       FS_NB, FS_OVERFLOW, active_frontier, active_pull_sum,
                       caps_for_parts, fstats_init, initial_affected,
                       publish_fstats)
from .graph import (Graph, bucket_band_counts, build_hybrid_rows,
                    choose_bucket_widths, next_pow2)
from .pagerank import EllBlock, PRParams
from .rank_step import rank_step
from ..obs.spans import get_registry as _obs
from ..obs.trace import trace_init, trace_record

try:  # JAX >= 0.4.35 spelling
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_loop(fn, mesh: Mesh, in_specs, out_specs):
    """shard_map a while-loop body, portably across JAX versions.

    JAX builds in the 0.4.3x line have no replication rule for `while` and
    require `check_rep=False`; newer builds dropped the kwarg once the rule
    existed. All convergence scalars here pass through `pmax` before the
    loop predicate, so skipping the static replication check is sound.
    """
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - kwarg removed in newer JAX
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)

__all__ = ["ShardedGraph", "build_sharded", "sharded_caps", "sharded_need",
           "shard_bounds", "shard_block_rows",
           "initial_affected_sharded", "shard_vector", "unshard_vector",
           "distributed_static_pagerank", "distributed_dfp_pagerank",
           "sharded_frontier_caps", "pagerank_step_specs"]


class ShardedGraph(NamedTuple):
    """Stacked per-shard hybrid layouts. Leading axis = shard.

    Each ELL degree bucket is one `EllBlock` with stacked arrays: rows
    [nd, cap_b] holds LOCAL row ids (sentinel n_loc), idx/mask
    [nd, cap_b, w_b] hold GLOBAL column ids / validity. Bucket widths and
    caps are shared across shards so stacking gives static shapes.
    """
    buckets: Tuple[EllBlock, ...]
    hi_pos: jnp.ndarray     # [nd, hi_cap] int32, LOCAL row ids (sentinel n_loc)
    hi_tiles: jnp.ndarray   # [nd, t_cap, tile] int32, GLOBAL column ids
    hi_tmask: jnp.ndarray   # [nd, t_cap, tile] f32
    hi_rowmap: jnp.ndarray  # [nd, t_cap] int32
    out_deg: jnp.ndarray    # [nd, n_loc] int32 (>=1)
    valid: jnp.ndarray      # [nd, n_loc] bool (False on padding vertices)
    n_true: int             # real |V| (for the (1-α)/|V| constant)

    @property
    def nd(self) -> int:
        return self.out_deg.shape[0]

    @property
    def n_loc(self) -> int:
        return self.out_deg.shape[1]


def shard_bounds(s: int, n_loc: int, n: int) -> Tuple[int, int]:
    """[lo, hi) of shard s's real vertices, clamped: a trailing shard may be
    entirely padding (lo == hi == n) when n_loc · nd overshoots |V|."""
    return min(s * n_loc, n), min((s + 1) * n_loc, n)


def shard_block_rows(g: Graph, s: int, n_loc: int):
    """(offsets, data) ragged-rows slice of shard s's contiguous vertex
    block in the transpose CSR — the input `build_hybrid_rows` consumes.
    Shared by `build_sharded` and the streaming `ShardedSnapshot` so the
    static and incremental layouts cannot drift."""
    lo, hi = shard_bounds(s, n_loc, g.n)
    off = g.t_offsets[lo:hi + 1] - g.t_offsets[lo]
    dat = g.t_sources[g.t_offsets[lo]:g.t_offsets[hi]]
    return off, dat


def sharded_need(indeg: np.ndarray, nd: int, n_loc: int, d_p: int, tile: int,
                 widths: Tuple[int, ...] = (),
                 band: bool = False) -> Tuple[int, int, Tuple[int, ...]]:
    """Worst-shard (high-slot, tile, per-bucket-slot) needs across the
    contiguous blocks — the raw sizes the pow2 capacity ladder is applied
    to. Bucket needs include each shard's padding rows (degree 0, parked in
    bucket 0 like `build_hybrid_rows` does). `band=True` counts each
    bucket's streaming hysteresis band (`bucket_band_counts`) instead of
    the initial placement census — what incremental snapshots must plan
    capacity against."""
    n = int(indeg.shape[0])
    need_hi = need_t = 1
    need_b = [1] * len(widths)
    for s in range(nd):
        lo, hi = shard_bounds(s, n_loc, n)
        blk = indeg[lo:hi]
        deg_hi = blk[blk > d_p]
        need_hi = max(need_hi, int(deg_hi.size))
        need_t = max(need_t, int(((deg_hi + tile - 1) // tile).sum()))
        if widths:
            if band:
                cnt = list(bucket_band_counts(blk, widths, d_p))
            else:
                low = blk[blk <= d_p]
                grp = np.searchsorted(widths, np.maximum(low, 1), side="left")
                cnt = np.bincount(grp, minlength=len(widths))
            cnt[0] += n_loc - (hi - lo)       # padding rows -> bucket 0
            need_b = [max(a, int(b)) for a, b in zip(need_b, cnt)]
    return need_hi, need_t, tuple(need_b)


def build_sharded(g: Graph, nd: int, d_p: int = 64, tile: int = 1024,
                  hi_cap: Optional[int] = None, t_cap: Optional[int] = None,
                  widths: Optional[Tuple[int, ...]] = None,
                  bucket_caps: Optional[Tuple[int, ...]] = None
                  ) -> ShardedGraph:
    """Host-side partitioner: contiguous vertex blocks, one hybrid per shard.

    Pads |V| to a multiple of nd with isolated vertices (masked out of
    updates and results). Each shard's block is laid out by the shared
    `build_hybrid_rows` primitive — the same vectorized ragged-fill passes
    as the single-device `build_hybrid`, no per-vertex Python loops. Bucket
    widths come from the *global* degree histogram so every shard shares
    one bucket structure; per-shard bucket/high/tile capacities are shared
    across shards so stacking gives static shapes, and default to pow2 of
    the max per-shard need (never pass smaller values than a previous build
    when re-sharding a growing graph — `sharded_caps` extracts the current
    signature).
    """
    n = g.n
    n_pad = ((n + nd - 1) // nd) * nd
    n_loc = n_pad // nd
    indeg = g.in_degree()
    out_deg = g.out_degree()
    if widths is None:
        widths = choose_bucket_widths(indeg, d_p)
    widths = tuple(int(w) for w in widths)

    # capacity discipline (DeviceSnapshot's pow2/never-shrink ladder): size
    # for the worst shard so the stacked shapes are jit-stable across shards
    # and, when the caller threads caps through batches, across snapshots.
    need_hi, need_t, need_b = sharded_need(indeg, nd, n_loc, d_p, tile,
                                           widths)
    if hi_cap is None:
        hi_cap = next_pow2(need_hi, 8)
    if t_cap is None:
        t_cap = next_pow2(need_t, 8)
    if bucket_caps is None:
        bucket_caps = tuple(next_pow2(nb, 8) for nb in need_b)
    assert need_hi <= hi_cap and need_t <= t_cap, \
        "sharded caps too small for this snapshot"
    assert all(nb <= c for nb, c in zip(need_b, bucket_caps)), \
        "sharded bucket caps too small for this snapshot"

    pieces = []
    for s in range(nd):
        off, dat = shard_block_rows(g, s, n_loc)
        pieces.append(build_hybrid_rows(off, dat, d_p=d_p, tile=tile,
                                        n_rows=n_loc, n_hi_cap=hi_cap,
                                        t_cap=t_cap, widths=widths,
                                        bucket_caps=bucket_caps))

    deg = np.ones((nd, n_loc), np.int32)
    valid = np.zeros((nd, n_loc), bool)
    for s in range(nd):
        lo, hi = shard_bounds(s, n_loc, n)
        deg[s, :hi - lo] = out_deg[lo:hi]
        valid[s, :hi - lo] = True

    buckets = tuple(
        EllBlock(
            rows=jnp.asarray(np.stack([p.buckets[b].rows for p in pieces])),
            idx=jnp.asarray(np.stack([p.buckets[b].idx for p in pieces])),
            mask=jnp.asarray(np.stack([p.buckets[b].mask for p in pieces])))
        for b in range(len(widths)))
    return ShardedGraph(
        buckets=buckets,
        hi_pos=jnp.asarray(np.stack([p.hi_ids for p in pieces])),
        hi_tiles=jnp.asarray(np.stack([p.hi_tiles for p in pieces])),
        hi_tmask=jnp.asarray(np.stack([p.hi_tmask for p in pieces])),
        hi_rowmap=jnp.asarray(np.stack([p.hi_rowmap for p in pieces])),
        out_deg=jnp.asarray(deg), valid=jnp.asarray(valid), n_true=n)


def sharded_caps(sg: ShardedGraph) -> dict:
    """Capacity signature — pass as **caps to `build_sharded` to rebuild a
    later snapshot of the same graph with identical device shapes."""
    widths = tuple(int(b.idx.shape[2]) for b in sg.buckets)
    return dict(d_p=widths[-1] if widths else 0,
                tile=int(sg.hi_tiles.shape[2]),
                hi_cap=int(sg.hi_pos.shape[1]), t_cap=int(sg.hi_tiles.shape[1]),
                widths=widths,
                bucket_caps=tuple(int(b.rows.shape[1]) for b in sg.buckets))


# ---------------------------------------------------------------------------
# Host <-> shard staging helpers
# ---------------------------------------------------------------------------

def shard_vector(x: np.ndarray, nd: int, fill=0) -> jnp.ndarray:
    """Stack a dense [n] host vector into [nd, n_loc] (pad with `fill`)."""
    x = np.asarray(x)
    n = x.shape[0]
    n_pad = ((n + nd - 1) // nd) * nd
    if n_pad != n:
        x = np.concatenate([x, np.full(n_pad - n, fill, x.dtype)])
    return jnp.asarray(x.reshape(nd, -1))


def unshard_vector(x, n: int) -> np.ndarray:
    """Inverse of `shard_vector`: [nd, n_loc] -> dense host [n]."""
    return np.asarray(x).reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("nd", "n_loc"))
def _initial_affected_stacked(nd, n_loc, del_src, del_dst, ins_src):
    dv, dn = initial_affected(nd * n_loc, del_src, del_dst, ins_src)
    return dv.reshape(nd, n_loc), dn.reshape(nd, n_loc)


def initial_affected_sharded(nd: int, n_loc: int, batch
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper Alg. 5 initialAffected on the stacked shard layout.

    `batch` is a DeviceBatch (ids may be padded with the id-n sentinel; a
    sentinel landing on a padding vertex is harmless — padding vertices have
    `valid=False` and no edges, so neither flag propagates). Returns stacked
    (δ_V [nd, n_loc], δ_N [nd, n_loc]) ready for `distributed_dfp_pagerank`,
    which performs the initial frontier expansion device-side at iteration 0.
    """
    return _initial_affected_stacked(nd, n_loc, batch.del_src, batch.del_dst,
                                     batch.ins_src)


# ---------------------------------------------------------------------------
# Local (per-shard) pull + update, consuming the gathered contribution vector
# ---------------------------------------------------------------------------

def _local_pull(sg_loc, c_full: jnp.ndarray) -> jnp.ndarray:
    dt = c_full.dtype
    n_loc = sg_loc["out_deg"].shape[0]
    low = jnp.zeros((n_loc,), dt)
    for blk in sg_loc["buckets"]:
        sums = jnp.sum(jnp.take(c_full, blk.idx, axis=0)
                       * blk.mask.astype(dt), axis=1)
        low = low.at[blk.rows].add(sums, mode="drop")
    tile_sums = jnp.sum(jnp.take(c_full, sg_loc["hi_tiles"], axis=0)
                        * sg_loc["hi_tmask"].astype(dt), axis=1)
    hi_cap = sg_loc["hi_pos"].shape[0]
    per_slot = jax.ops.segment_sum(tile_sums, sg_loc["hi_rowmap"],
                                   num_segments=hi_cap)
    return low.at[sg_loc["hi_pos"]].add(per_slot, mode="drop")


def _local_pull_max(sg_loc, x_full: jnp.ndarray) -> jnp.ndarray:
    dt = x_full.dtype
    n_loc = sg_loc["out_deg"].shape[0]
    low = jnp.zeros((n_loc,), dt)
    for blk in sg_loc["buckets"]:
        rmax = jnp.max(jnp.take(x_full, blk.idx, axis=0)
                       * blk.mask.astype(dt), axis=1, initial=0)
        low = low.at[blk.rows].max(rmax, mode="drop")
    tmax = jnp.max(jnp.take(x_full, sg_loc["hi_tiles"], axis=0)
                   * sg_loc["hi_tmask"].astype(dt), axis=1, initial=0)
    hi_cap = sg_loc["hi_pos"].shape[0]
    per_slot = jnp.maximum(
        jax.ops.segment_max(tmax, sg_loc["hi_rowmap"], num_segments=hi_cap), 0)
    return low.at[sg_loc["hi_pos"]].max(per_slot, mode="drop")


_FIELDS = ("buckets", "hi_pos", "hi_tiles", "hi_tmask",
           "hi_rowmap", "out_deg", "valid")


def _as_dict(sg: ShardedGraph) -> dict:
    return {k: getattr(sg, k) for k in _FIELDS}


def _squeeze_shard(sgd: dict) -> dict:
    """Inside shard_map each array has leading dim 1 — drop it."""
    return jax.tree.map(lambda v: v[0], sgd)


def _make_loop(axis, params: PRParams, n_true: int, *, dfp: bool,
               compact_frontier: bool = False, delta_every: int = 1,
               trace: bool = False, frontier_caps=None,
               health: bool = False):
    """Build the per-shard while-loop body. `axis` is the (tuple of) mesh
    axis name(s) the vertex dimension is sharded over.

    The per-iteration math is `core.rank_step.rank_step` on this shard's
    slice — the same single implementation the dense engine uses — wrapped
    in the two collectives the 1-D partition needs: the contribution
    all-gather and the convergence pmax. Frontier expansion (dfp) pulls the
    gathered δ_N through the same local layout, *including at iteration 0*,
    which is the paper's initial expansion (line 9) performed device-side:
    callers seed δ_N with the updated sources (`initial_affected_sharded`)
    instead of pre-expanding on the host.

    `compact_frontier` gathers δ_N as uint8 instead of the rank dtype
    (DESIGN.md §5: the frontier all-gather shrinks 4-8x; the pull-max
    upcasts locally). `delta_every=k` evaluates the global L-inf all-reduce
    every k iterations only — the straggler/latency mitigation of DESIGN.md
    §8: up to k-1 surplus (cheap, local) iterations traded for k-fold fewer
    global syncs.

    `trace` carries an obs.trace.TraceBuffer through the loop; its channels
    come out of psum/pmax collectives so the buffer is replicated across
    shards (out_spec P()). Tracing adds two small per-iteration collectives
    and never feeds back into the rank math; with delta_every>1 the traced
    L∞ is exact every iteration even though the loop predicate still only
    sees it every k-th.

    `frontier_caps` (core.frontier.FrontierCaps over the PER-SHARD layout
    shapes — `caps_for_parts`) switches the rank pull to the compacted
    active lists: each shard compacts its own δ_V slice against its own
    layout and pulls only the active rows/tiles from the gathered
    contribution vector; a shard whose lists overflow runs its dense local
    pull for that iteration (per-shard lax.cond — sound because neither
    branch holds a collective, so shards may diverge freely). The loop then
    also carries a frontier-stats vector, psum-reduced on exit."""

    def loop(sgd: dict, r0, dv0, dn0):
        sgl = _squeeze_shard(sgd)
        r0, dv0, dn0 = r0[0], dv0[0], dn0[0]
        dt = r0.dtype
        d = sgl["out_deg"].astype(dt)
        valid = sgl["valid"]
        n_loc = valid.shape[0]

        def body(state):
            r, dv, dn, _, i, tb, fs = state
            if dfp:
                gdt = jnp.uint8 if compact_frontier else dt
                dn_full = jax.lax.all_gather(dn.astype(gdt), axis, tiled=True)
                grow = _local_pull_max(sgl, dn_full.astype(dt)) > 0
                dv = (dv | grow) & valid
            c_full = jax.lax.all_gather(r / d, axis, tiled=True)
            dv_in = dv & valid
            if frontier_caps is not None:
                af = active_frontier(sgl["buckets"], sgl["hi_pos"],
                                     sgl["hi_rowmap"], dv_in, frontier_caps)
                s = jax.lax.cond(
                    af.overflow,
                    lambda: _local_pull(sgl, c_full),
                    lambda: active_pull_sum(
                        sgl["buckets"], sgl["hi_pos"], sgl["hi_tiles"],
                        sgl["hi_tmask"], sgl["hi_rowmap"], af, c_full,
                        n_loc))
                ok = (~af.overflow).astype(jnp.int32)
                fs = fs.at[FS_ITERS].add(1).at[FS_COMPACT].add(ok) \
                       .at[FS_OVERFLOW].add(1 - ok) \
                       .at[FS_ACTIVE_ROWS].add(af.n_rows * ok) \
                       .at[FS_ACTIVE_TILES].add(af.n_tiles * ok)
                if len(sgl["buckets"]):
                    fs = fs.at[FS_NB:].add(af.bucket_counts * ok)
            else:
                s = _local_pull(sgl, c_full)
            r_new, dv, dn_new, local = rank_step(
                s, r, dv_in, sgl["out_deg"], alpha=params.alpha,
                n_norm=n_true, tau_f=params.tau_f, tau_p=params.tau_p,
                prune=dfp, closed_form=dfp, track_frontier=dfp)
            if not dfp:
                dn_new = dn
            gmax = jax.lax.pmax(local, axis)
            if delta_every > 1:
                check = (i + 1) % delta_every == 0
                delta = jnp.where(check, gmax, jnp.asarray(jnp.inf, dt))
            else:
                delta = gmax
            if trace:
                counts = jnp.stack([
                    jnp.sum(dv_in), jnp.sum(dn_new),
                    jnp.sum(dv_in) - jnp.sum(dv & valid)]).astype(jnp.int32)
                counts = jax.lax.psum(counts, axis)
                tb = trace_record(tb, i, linf=gmax, frontier=counts[0],
                                  delta_n=counts[1] if dfp else 0,
                                  pruned=counts[2] if dfp else 0)
            return r_new, dv, dn_new, delta, i + 1, tb, fs

        def cond(state):
            delta, i = state[3], state[4]
            return (delta > params.tau) & (i < params.max_iter)

        tb0 = trace_init(params.max_iter, dt,
                         "dfp_1d" if dfp else "static_1d") if trace \
            else jnp.asarray(0, jnp.int32)
        nb = len(sgl["buckets"])
        init = (r0, dv0, dn0, jnp.asarray(jnp.inf, dt),
                jnp.asarray(0, jnp.int32), tb0, fstats_init(nb))
        r, dv, dn, delta, iters, tb, fs = jax.lax.while_loop(cond, body, init)
        out = [r[None], iters]
        if trace:
            out.append(tb)
        if health:
            # guard.health word, replicated: delta came through pmax, the
            # mass is one extra psum over the valid slice. A delta left at
            # the inf skip-sentinel (delta_every>1 exhausting the budget
            # between checks) clamps to H_MAX_ITER inside solve_health.
            mass = jax.lax.psum(jnp.sum(jnp.where(valid, r, 0)), axis)
            out.append(solve_health(delta, iters, mass, params))
        if frontier_caps is not None:
            out.append(jax.lax.psum(fs, axis))
        return tuple(out)

    return loop


def _specs(mesh: Mesh):
    axis = tuple(mesh.axis_names)
    shard = P(axis)
    return axis, shard


def pagerank_step_specs(mesh: Mesh):
    """(in_specs, out_specs) used by the dry-run lowering for this workload."""
    axis, shard = _specs(mesh)
    return shard, axis


def distributed_static_pagerank(mesh: Mesh, sg: ShardedGraph, r0: jnp.ndarray,
                                params: PRParams = PRParams(),
                                delta_every: int = 1, trace: bool = False,
                                health: bool = False):
    """r0: [nd, n_loc] stacked ranks. Returns (ranks [nd, n_loc], iters),
    plus a replicated obs.trace.TraceBuffer when ``trace=True`` and a
    replicated guard.health word (last) when ``health=True``."""
    axis, shard = _specs(mesh)
    nd, n_loc = sg.out_deg.shape
    on = jnp.ones((nd, n_loc), jnp.bool_)
    off = jnp.zeros((nd, n_loc), jnp.bool_)
    loop = _make_loop(axis, params, sg.n_true, dfp=False,
                      delta_every=delta_every, trace=trace, health=health)
    out_specs = [shard, P()]
    if trace:
        out_specs.append(P())
    if health:
        out_specs.append(P())
    fn = shard_map_loop(loop, mesh,
                        ({k: shard for k in _FIELDS}, shard, shard, shard),
                        tuple(out_specs))
    with _obs().span("solve.static_1d", annotate=True):
        return jax.jit(fn)(_as_dict(sg), r0, on, off)


def sharded_frontier_caps(sg: ShardedGraph, est: int,
                          headroom: int = 16):
    """FrontierCaps over the PER-SHARD layout shapes for `frontier_caps` of
    `distributed_dfp_pagerank`. `est` is the expected initial frontier size
    of the worst shard (a global estimate works too — caps only affect
    speed, never correctness)."""
    return caps_for_parts(
        tuple(int(b.rows.shape[1]) for b in sg.buckets),
        int(sg.hi_pos.shape[1]), int(sg.hi_tiles.shape[1]),
        sg.n_loc, est, headroom)


def distributed_dfp_pagerank(mesh: Mesh, sg: ShardedGraph, r_prev: jnp.ndarray,
                             dv0: jnp.ndarray, dn0: jnp.ndarray,
                             params: PRParams = PRParams(),
                             delta_every: int = 1, trace: bool = False,
                             frontier_caps=None, health: bool = False):
    """DF-P on the cluster: dv0/dn0 are the initial affected / to-expand
    flags ([nd, n_loc], from `initial_affected_sharded`). Iteration 0 pulls
    dn0 through the layout — the paper's initial frontier expansion — so
    callers seed raw flags; pre-expanded dv0 (with dn0 zeroed) also works.
    ``trace=True`` appends a replicated obs.trace.TraceBuffer;
    ``health=True`` appends a replicated guard.health word (before the
    frontier stats, which stay last).
    ``frontier_caps`` (`sharded_frontier_caps`) compacts each shard's rank
    pull to its active rows/tiles — identical results, frontier.* obs
    counters published host-side."""
    axis, shard = _specs(mesh)
    loop = _make_loop(axis, params, sg.n_true, dfp=True,
                      delta_every=delta_every, trace=trace,
                      frontier_caps=frontier_caps, health=health)
    out_specs = [shard, P()]
    if trace:
        out_specs.append(P())
    if health:
        out_specs.append(P())
    if frontier_caps is not None:
        out_specs.append(P())
    fn = shard_map_loop(loop, mesh,
                        ({k: shard for k in _FIELDS}, shard, shard, shard),
                        tuple(out_specs))
    with _obs().span("solve.dfp_1d", annotate=True):
        out = jax.jit(fn)(_as_dict(sg), r_prev, dv0, dn0)
    if frontier_caps is not None:
        *out, fs = out
        publish_fstats(fs)
        out = tuple(out)
    return out
