"""Paper Alg. 5 affected-set machinery + device-side frontier compaction.

Marking (unchanged since PR 1): `initial_affected` scatters O(|Δ|) flags,
`expand_affected` is the dense pull-based expansion (every vertex pulls the
OR of δ_N over its in-neighbors in G^t), `reach_affected` the DT fixpoint.

Compaction (PR 8, the O(frontier·degree) layer): dense masks make every
sweep O(|E|) regardless of how small δ_V is — the mask only gates the
*write*. This module turns δ_V into *active gather lists* over the hybrid
layout instead, with static shapes so jitted loops never recompile:

  * `stream_compact` — cumsum-based compaction of a flag vector into a
    fixed-capacity index list (the GPU stream-compaction primitive, in XLA);
  * `FrontierCaps` — the static pow2 capacity plan (hashable, a jit static
    arg). Capacities never shrink (`merge_caps`), so a streamed session
    re-uses one compiled loop across batches;
  * `active_frontier` — per-bucket active-slot lists + active hi-slot and
    CSR-tile lists from δ_V, with an `overflow` flag when any list is
    truncated (callers fall back to the full sweep for that iteration —
    capacity guesses affect speed, never correctness);
  * `active_pull_sum` / `update_ranks_active` — the rank pull (and the
    full Alg. 3 sweep) restricted to the active lists: per-iteration edge
    work is O(Σ_b k_b·w_b + k_t·tile), the paper's frontier·degree bound;
  * `push_expand` / `expand_frontier` — the paper's out-edge expansion
    driven by the compacted δ_N worklist (low buckets: one ELL row per
    worklist entry; high out-degree: compacted tile walk — Alg. 5's
    out-degree partitioning), with the dense pull as the overflow branch.

Both the single-device `DeviceGraph` and the per-shard layouts (which lack
`bucket_of`/`slot_of`) are served: compaction is *slot-based* — a bucket's
active rows are found by gathering δ_V at the bucket's row ids, never by
indexing vertex ids into bucket membership tables.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .graph import next_pow2
from .pagerank import DeviceGraph, pull_max
from .rank_step import rank_step

__all__ = [
    "initial_affected", "expand_affected", "reach_affected",
    "stream_compact", "FrontierCaps", "ActiveFrontier", "caps_for",
    "caps_for_parts", "merge_caps", "plan_capacity", "active_frontier",
    "active_pull_sum", "update_ranks_active", "push_expand",
    "expand_frontier", "fstats_init", "publish_fstats",
    "FS_ITERS", "FS_COMPACT", "FS_OVERFLOW", "FS_ACTIVE_ROWS",
    "FS_ACTIVE_TILES", "FS_PUSH", "FS_PULL", "FS_EXPAND_WORK", "FS_NB",
]


def initial_affected(n: int, del_src: jnp.ndarray, del_dst: jnp.ndarray,
                     ins_src: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 5 initialAffected: δ_N[u]=1 for every updated source u; δ_V[v]=1
    for every deletion target v. Inputs may be padded with id == n (dropped)."""
    dv = jnp.zeros((n,), jnp.bool_)
    dn = jnp.zeros((n,), jnp.bool_)
    dn = dn.at[del_src].set(True, mode="drop")
    dn = dn.at[ins_src].set(True, mode="drop")
    dv = dv.at[del_dst].set(True, mode="drop")
    return dv, dn


def expand_affected(dg: DeviceGraph, dv: jnp.ndarray, dn: jnp.ndarray
                    ) -> jnp.ndarray:
    """δ_V'[v] = δ_V[v] OR (∃ u ∈ G^t.in(v): δ_N[u]) — dense O(|E|) pull.

    NOTE: `dg` here must be the hybrid layout of the *current graph's
    transpose* — i.e. rows are in-neighbors in G^t, which is exactly the rank
    pull structure, so expansion re-uses it (DESIGN.md §2). The compacted
    engines use this only as the worklist-overflow fallback; see
    `expand_frontier`.
    """
    pulled = pull_max(dg, dn.astype(jnp.float32))
    return dv | (pulled > 0.5)


def reach_affected(dg: DeviceGraph, seeds: jnp.ndarray,
                   max_steps: int | None = None) -> jnp.ndarray:
    """Dynamic Traversal marking: all vertices reachable (along out-edges)
    from seed vertices, via pull-based BFS fixpoint on the transpose layout.
    Used by the DT baseline. `seeds` is a dense bool [n] mask."""
    n = dg.n
    max_steps = n if max_steps is None else max_steps

    def body(state):
        vis, _, i = state
        nxt = vis | (pull_max(dg, vis.astype(jnp.float32)) > 0.5)
        changed = jnp.any(nxt != vis)
        return nxt, changed, i + 1

    def cond(state):
        _, changed, i = state
        return changed & (i < max_steps)

    vis, _, _ = jax.lax.while_loop(
        cond, body, (seeds, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
    return vis


# ---------------------------------------------------------------------------
# Stream compaction + capacity plans
# ---------------------------------------------------------------------------

def stream_compact(flags: jnp.ndarray, k: int, fill: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Indices of set flags, order-preserving, into a static [k] list.

    Stream compaction spelled scatter-free: keying each lane with its own
    index (dead lanes key past the end) and sorting brings the set flags to
    the front in order — XLA lowers the sort to a vectorized bitonic/merge
    network, whereas the textbook cumsum + scatter form serializes on the
    scatter (~2x slower on CPU, worse on TPU where arbitrary-index scatter
    is the weakest op). Destinations beyond k are dropped (callers must
    treat count > k as overflow — the list is then truncated). Dead lanes
    hold `fill`. Returns (idx [k] int32, count)."""
    ln = flags.shape[0]
    keys = jnp.where(flags, jnp.arange(ln, dtype=jnp.int32), ln)
    if k > ln:                      # caps may overshoot short flag vectors
        keys = jnp.pad(keys, (0, k - ln), constant_values=ln)
    idx = jax.lax.sort(keys, is_stable=False)[:k]
    idx = jnp.where(idx >= ln, fill, idx)
    return idx, jnp.sum(flags, dtype=jnp.int32)


class FrontierCaps(NamedTuple):
    """Static compaction capacities (a hashable jit static argument).

    All fields are ints on the pow2 ladder (never-shrink across a session —
    `merge_caps` — so capacity growth, not frontier churn, is the only
    recompile trigger). `bucket[b]` bounds bucket b's active-slot list,
    `hi`/`tiles` the active high-slot / CSR-tile lists of the pull layout,
    `dn` the push-expansion vertex worklist, `fwd_tiles` the forward
    layout's tile worklist (0 = uncompacted full tile list: affected hubs
    legitimately need all their tiles and truncating them thrashes the
    fallback — DESIGN.md §4's refuted-tile-compaction lesson)."""
    bucket: Tuple[int, ...]
    hi: int
    tiles: int
    dn: int
    fwd_tiles: int = 0


def plan_capacity(est: int, n: int, headroom: int = 16) -> int:
    """One shared sizing rule: pow2(est·headroom), clamped to n, floor 16."""
    return min(next_pow2(max(int(est), 1) * headroom), max(next_pow2(n), 16))


def caps_for_parts(bucket_caps: Tuple[int, ...], n_hi_cap: int, t_cap: int,
                   n: int, est: int, headroom: int = 16) -> FrontierCaps:
    """Capacity plan from layout shapes + an expected initial frontier size.

    Each list is bounded by both the plan size and its layout capacity (a
    bucket can never hold more active rows than it has slots, so clamped
    lists cannot overflow on that side)."""
    k = plan_capacity(est, n, headroom)
    return FrontierCaps(
        bucket=tuple(min(k, int(c)) for c in bucket_caps),
        hi=min(k, int(n_hi_cap)),
        tiles=min(next_pow2(k), int(t_cap)),
        dn=k,
        fwd_tiles=0)


def caps_for(dg: DeviceGraph, est: int, headroom: int = 16) -> FrontierCaps:
    """`caps_for_parts` reading the shapes off a staged DeviceGraph."""
    return caps_for_parts(
        tuple(int(b.rows.shape[0]) for b in dg.buckets),
        dg.n_hi_cap, int(dg.hi_tiles.shape[0]), dg.n, est, headroom)


def merge_caps(a: Optional[FrontierCaps], b: FrontierCaps) -> FrontierCaps:
    """Elementwise max — the never-shrink discipline across a session."""
    if a is None:
        return b
    return FrontierCaps(
        bucket=tuple(max(x, y) for x, y in zip(a.bucket, b.bucket)),
        hi=max(a.hi, b.hi), tiles=max(a.tiles, b.tiles),
        dn=max(a.dn, b.dn), fwd_tiles=max(a.fwd_tiles, b.fwd_tiles))


# ---------------------------------------------------------------------------
# Active gather lists over the hybrid layout
# ---------------------------------------------------------------------------

class ActiveFrontier(NamedTuple):
    """δ_V compacted against one hybrid layout (static shapes from caps).

    Sentinels: bucket_sel[b] dead lanes = cap_b, hi_sel = n_hi_cap,
    tile_sel = t_cap. `overflow` is the single validity bit: when True some
    list was truncated and NONE of the lists may be used for an update —
    callers run the dense full sweep for that iteration instead."""
    bucket_sel: Tuple[jnp.ndarray, ...]   # per bucket [k_b] slot ids
    hi_sel: jnp.ndarray                   # [k_h] hi slot ids
    tile_sel: jnp.ndarray                 # [k_t] CSR tile ids
    bucket_counts: jnp.ndarray            # [nb] int32 active rows per bucket
    n_rows: jnp.ndarray                   # scalar int32 (buckets + hi)
    n_tiles: jnp.ndarray                  # scalar int32
    overflow: jnp.ndarray                 # scalar bool


def active_frontier(buckets, hi_ids: jnp.ndarray, hi_rowmap: jnp.ndarray,
                    dv: jnp.ndarray, caps: FrontierCaps) -> ActiveFrontier:
    """Compact δ_V into active gather lists, slot-based.

    Works on a DeviceGraph's parts or one shard's squeezed layout (pass
    `hi_pos` as `hi_ids` there): a bucket's active slots are found by
    gathering δ_V at the bucket's row ids (sentinel rows read False), the
    active tile list by gathering the hi-slot activity through the
    tile→slot map — no vertex-id→slot tables needed."""
    assert len(caps.bucket) == len(buckets), \
        "FrontierCaps bucket arity != layout bucket arity"
    sels, counts = [], []
    overflow = jnp.asarray(False)
    for blk, kb in zip(buckets, caps.bucket):
        on = jnp.take(dv, blk.rows, mode="fill", fill_value=False)
        sel, cnt = stream_compact(on, kb, blk.rows.shape[0])
        sels.append(sel)
        counts.append(cnt)
        overflow = overflow | (cnt > kb)
    on_hi = jnp.take(dv, hi_ids, mode="fill", fill_value=False)
    hi_sel, hi_cnt = stream_compact(on_hi, caps.hi, hi_ids.shape[0])
    tile_on = jnp.take(on_hi, hi_rowmap)
    tile_sel, t_cnt = stream_compact(tile_on, caps.tiles,
                                     hi_rowmap.shape[0])
    overflow = overflow | (hi_cnt > caps.hi) | (t_cnt > caps.tiles)
    bucket_counts = (jnp.stack(counts) if counts
                     else jnp.zeros((0,), jnp.int32))
    n_rows = (jnp.sum(bucket_counts, dtype=jnp.int32) if counts
              else jnp.asarray(0, jnp.int32)) + hi_cnt
    return ActiveFrontier(tuple(sels), hi_sel, tile_sel, bucket_counts,
                          n_rows, t_cnt, overflow)


def active_pull_sum(buckets, hi_ids, hi_tiles, hi_tmask, hi_rowmap,
                    af: ActiveFrontier, c: jnp.ndarray, n_out: int
                    ) -> jnp.ndarray:
    """`pull_sum` restricted to the active lists: dense [n_out] sums that are
    exact for every active row and zero elsewhere (callers mask by δ_V, so
    inactive lanes never feed the rank math). Edge work is
    O(Σ_b k_b·w_b + k_t·tile) — the frontier·degree bound. `c` may be longer
    than n_out (sharded shards gather global columns into local rows).

    Only valid when `af.overflow` is False (truncated lists would silently
    drop in-edges of hubs)."""
    dt = c.dtype
    out = jnp.zeros((n_out,), dt)
    for blk, sel in zip(buckets, af.bucket_sel):
        rows = jnp.take(blk.rows, sel, mode="fill", fill_value=n_out)
        idx = jnp.take(blk.idx, sel, axis=0, mode="fill", fill_value=0)
        msk = jnp.take(blk.mask, sel, axis=0, mode="fill", fill_value=0.0)
        sums = jnp.sum(jnp.take(c, idx, axis=0) * msk.astype(dt), axis=1)
        out = out.at[rows].add(sums, mode="drop")
    tiles = jnp.take(hi_tiles, af.tile_sel, axis=0, mode="fill",
                     fill_value=0)
    tmask = jnp.take(hi_tmask, af.tile_sel, axis=0, mode="fill",
                     fill_value=0.0)
    tsums = jnp.sum(jnp.take(c, tiles, axis=0) * tmask.astype(dt), axis=1)
    slot = jnp.take(hi_rowmap, af.tile_sel, mode="fill", fill_value=0)
    owner = jnp.take(hi_ids, slot)        # dead lanes add 0.0 — inert
    return out.at[owner].add(tsums, mode="drop")


def update_ranks_active(dg: DeviceGraph, r: jnp.ndarray, dv: jnp.ndarray,
                        af: ActiveFrontier, *, alpha: float, tau_f: float,
                        tau_p: float, prune: bool, closed_form: bool,
                        track_frontier: bool):
    """One Alg. 3 sweep whose pull touches only the active lists.

    Same contract (and bit-identical outputs, lane for lane: each row's
    in-edge sum is reduced in the same order as the dense pull) as
    `core.pagerank.update_ranks` whenever `af` covers δ_V — i.e. whenever
    `af.overflow` is False, which callers must guarantee (lax.cond on it)."""
    s = active_pull_sum(dg.buckets, dg.hi_ids, dg.hi_tiles, dg.hi_tmask,
                        dg.hi_rowmap, af, r / dg.out_deg.astype(r.dtype),
                        dg.n)
    return rank_step(s, r, dv, dg.out_deg, alpha=alpha, n_norm=dg.n,
                     tau_f=tau_f, tau_p=tau_p, prune=prune,
                     closed_form=closed_form, track_frontier=track_frontier)


# ---------------------------------------------------------------------------
# Push-style expansion (paper Alg. 5 expandAffected, worklist-driven)
# ---------------------------------------------------------------------------

def push_expand(fwd: DeviceGraph, dn: jnp.ndarray, kn: int,
                kt: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Out-neighbors of the compacted δ_N worklist, marked.

    The paper's out-degree-partitioned kernel pair on the forward hybrid
    layout: low out-degree sources walk their own ELL row (one worklist
    entry = one [w_b] row gather); high out-degree sources walk their tile
    lists through a *compacted* tile worklist (kt = 0 keeps the dense tile
    walk gated by the activity mask — never overflows). Work is
    Σ out-degree(worklist), Alg. 5's bound. Returns (marks [n] bool,
    overflow) — marks are only complete when overflow is False."""
    n = fwd.n
    src, n_src = stream_compact(dn, kn, n)
    overflow = n_src > kn
    nb = len(fwd.buckets)
    b_of = jnp.take(fwd.bucket_of, src, mode="fill", fill_value=nb)
    s_of = jnp.take(fwd.slot_of, src, mode="fill", fill_value=0)
    out = jnp.zeros((n + 1,), jnp.bool_)
    for bi, blk in enumerate(fwd.buckets):
        slot = jnp.where(b_of == bi, s_of, blk.rows.shape[0])
        nbr = jnp.take(blk.idx, slot, axis=0, mode="fill", fill_value=0)
        msk = jnp.take(blk.mask, slot, axis=0, mode="fill", fill_value=0.0)
        tgt = jnp.where(msk > 0, nbr, n)
        out = out.at[tgt.reshape(-1)].set(True, mode="drop")
    # high-out-degree worklist entries: their tile lists
    hi_aff = jnp.take(dn, fwd.hi_ids, mode="fill", fill_value=False)
    tile_on = jnp.take(hi_aff, fwd.hi_rowmap)
    if kt:
        tsel, n_t = stream_compact(tile_on, kt, fwd.hi_tiles.shape[0])
        overflow = overflow | (n_t > kt)
        tiles = jnp.take(fwd.hi_tiles, tsel, axis=0, mode="fill",
                         fill_value=0)
        tmask = jnp.take(fwd.hi_tmask, tsel, axis=0, mode="fill",
                         fill_value=0.0)
        tgt2 = jnp.where(tmask > 0, tiles, n)
    else:
        tgt2 = jnp.where((fwd.hi_tmask > 0) & tile_on[:, None],
                         fwd.hi_tiles, n)
    out = out.at[tgt2.reshape(-1)].set(True, mode="drop")
    return out[:n], overflow


def expand_frontier(dg: DeviceGraph, fwd: DeviceGraph, dv: jnp.ndarray,
                    dn: jnp.ndarray, caps: FrontierCaps
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """δ_V ∪ out-neighbors(δ_N): push-style when the worklist fits its caps,
    dense pull (`expand_affected`) otherwise — chosen per iteration inside
    the jitted loop, so a one-off frontier spike costs one full sweep, not a
    recompile. Returns (δ_V', stats [work, pushed, pulled] int32)."""
    n_dn = jnp.sum(dn, dtype=jnp.int32)
    hi_aff = jnp.take(dn, fwd.hi_ids, mode="fill", fill_value=False)
    n_t = jnp.sum(jnp.take(hi_aff, fwd.hi_rowmap), dtype=jnp.int32)
    ovf = n_dn > caps.dn
    if caps.fwd_tiles:
        ovf = ovf | (n_t > caps.fwd_tiles)

    def pull_branch():
        return expand_affected(dg, dv, dn)

    def push_branch():
        marks, _ = push_expand(fwd, dn, caps.dn, caps.fwd_tiles)
        return dv | marks

    dv_new = jax.lax.cond(ovf, pull_branch, push_branch)
    one = jnp.asarray(1, jnp.int32)
    zero = jnp.asarray(0, jnp.int32)
    stats = jnp.stack([n_dn,
                       jnp.where(ovf, zero, one),
                       jnp.where(ovf, one, zero)])
    return dv_new, stats


# ---------------------------------------------------------------------------
# frontier.* observability (device-accumulated, host-published)
# ---------------------------------------------------------------------------

# fstats vector layout: fixed slots, then one active-row counter per bucket.
FS_ITERS = 0          # loop iterations run
FS_COMPACT = 1        # iterations that used the active lists
FS_OVERFLOW = 2       # iterations that fell back to the full sweep
FS_ACTIVE_ROWS = 3    # Σ active rows over compacted iterations
FS_ACTIVE_TILES = 4   # Σ active CSR tiles over compacted iterations
FS_PUSH = 5           # push-style expansions
FS_PULL = 6           # dense pull expansions (worklist overflow)
FS_EXPAND_WORK = 7    # Σ δ_N worklist sizes fed to expansion
FS_NB = 8             # per-bucket active-row counters start here

_FS_NAMES = ("iters", "compact_iters", "compaction_overflows",
             "active_rows", "active_tiles", "push_expands", "pull_expands",
             "expansion_work")


def fstats_init(n_buckets: int) -> jnp.ndarray:
    """Zeroed frontier-stats accumulator carried through a jitted loop."""
    return jnp.zeros((FS_NB + n_buckets,), jnp.int32)


def publish_fstats(fs, registry=None) -> None:
    """Fold a loop's fstats vector into the host registry (frontier.*)."""
    import numpy as np
    from ..obs.spans import get_registry
    reg = registry if registry is not None else get_registry()
    vals = [int(v) for v in np.asarray(fs)]
    for name, v in zip(_FS_NAMES, vals):
        reg.inc(f"frontier.{name}", v)
    for b, v in enumerate(vals[FS_NB:]):
        reg.inc(f"frontier.active_rows.b{b}", v)
