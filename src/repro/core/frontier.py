"""Paper Alg. 5 — parallel marking of affected vertices, scatter-free.

`initial_affected` is a direct translation (the paper scatters O(|Δ|) flags —
that stays a scatter; it is tiny and batched). `expand_affected` is the TPU
adaptation: instead of scattering each flagged vertex's out-neighbors (the
paper's out-degree-partitioned kernel pair), every vertex *pulls* the OR of
δ_N over its in-neighbors in G^t — the same transposed structures used for
rank computation. Identical fixpoint, no atomics, one write per vertex.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .pagerank import DeviceGraph, pull_max

__all__ = ["initial_affected", "expand_affected", "reach_affected"]


def initial_affected(n: int, del_src: jnp.ndarray, del_dst: jnp.ndarray,
                     ins_src: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 5 initialAffected: δ_N[u]=1 for every updated source u; δ_V[v]=1
    for every deletion target v. Inputs may be padded with id == n (dropped)."""
    dv = jnp.zeros((n,), jnp.bool_)
    dn = jnp.zeros((n,), jnp.bool_)
    dn = dn.at[del_src].set(True, mode="drop")
    dn = dn.at[ins_src].set(True, mode="drop")
    dv = dv.at[del_dst].set(True, mode="drop")
    return dv, dn


def expand_affected(dg: DeviceGraph, dv: jnp.ndarray, dn: jnp.ndarray
                    ) -> jnp.ndarray:
    """δ_V'[v] = δ_V[v] OR (∃ u ∈ G^t.in(v): δ_N[u]).

    NOTE: `dg` here must be the hybrid layout of the *current graph's
    transpose* — i.e. rows are in-neighbors in G^t, which is exactly the rank
    pull structure, so expansion re-uses it (DESIGN.md §2).
    """
    pulled = pull_max(dg, dn.astype(jnp.float32))
    return dv | (pulled > 0.5)


def reach_affected(dg: DeviceGraph, seeds: jnp.ndarray,
                   max_steps: int | None = None) -> jnp.ndarray:
    """Dynamic Traversal marking: all vertices reachable (along out-edges)
    from seed vertices, via pull-based BFS fixpoint on the transpose layout.
    Used by the DT baseline. `seeds` is a dense bool [n] mask."""
    n = dg.n
    max_steps = n if max_steps is None else max_steps

    def body(state):
        vis, _, i = state
        nxt = vis | (pull_max(dg, vis.astype(jnp.float32)) > 0.5)
        changed = jnp.any(nxt != vis)
        return nxt, changed, i + 1

    def cond(state):
        _, changed, i = state
        return changed & (i < max_steps)

    vis, _, _ = jax.lax.while_loop(
        cond, body, (seeds, jnp.asarray(True), jnp.asarray(0, jnp.int32)))
    return vis
