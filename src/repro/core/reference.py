"""Pure-numpy oracles for tests and error measurement (paper §5.1.5).

`reference_pagerank` is the paper's reference: Static PageRank on the updated
graph at an extremely low tolerance (τ = 1e-100, i.e. it always runs to the
500-iteration cap), used as ground truth for L1 error of every approach.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph

__all__ = ["reference_pagerank", "numpy_pagerank", "l1_error"]


def numpy_pagerank(g: Graph, alpha: float = 0.85, tau: float = 1e-10,
                   max_iter: int = 500, r0: np.ndarray | None = None):
    """Pull-based synchronous power iteration in float64 (Eq. 1)."""
    n = g.n
    out_deg = g.out_degree().astype(np.float64)
    r = np.full(n, 1.0 / n) if r0 is None else np.asarray(r0, np.float64).copy()
    src = g.t_sources  # in-neighbors, CSR over t_offsets
    seg = np.repeat(np.arange(n), np.diff(g.t_offsets))
    it = 0
    for it in range(1, max_iter + 1):
        c = r / out_deg
        s = np.bincount(seg, weights=c[src], minlength=n)
        r_new = (1.0 - alpha) / n + alpha * s
        delta = np.max(np.abs(r_new - r))
        r = r_new
        if delta <= tau:
            break
    return r, it


def reference_pagerank(g: Graph, alpha: float = 0.85, max_iter: int = 500):
    return numpy_pagerank(g, alpha=alpha, tau=1e-100, max_iter=max_iter)[0]


def l1_error(r: np.ndarray, ref: np.ndarray) -> float:
    return float(np.sum(np.abs(np.asarray(r, np.float64) - ref)))
