"""2-D edge-partitioned PageRank (beyond-paper; DESIGN.md §6).

The paper's pull model on a 1-D vertex partition all-gathers the FULL
contribution vector c (V·4 B per device per iteration) — collective-bound at
scale. Classic 2-D SpMV blocking fixes this: on an (r × c) mesh, device
(i, j) owns the edge block with sources in row-range(i) and destinations in
row-range(j); per iteration it

  1. all-gathers c along 'model'  -> c_row [V/r]      (V/r bytes, not V)
  2. pulls its edge block         -> y_partial [V/c]
  3. psum_scatters y along 'data' -> its V/(r·c) piece of destination range j
  4. collective-permutes (i,j)->(j,i) to return the piece to its owner
     (ownership is row-major block b = i·c + j).

Per-device collective bytes drop from ~2·V·4 to ~2·(V/r)·4 (+V/(r·c) for the
transpose) — 16x on the 16×16 pod. Frontier expansion (δ_N OR-pull) rides the
same schedule with sum-as-OR (flags are 0/1, so Σ>0 ⇔ ∨). Everything stays
scatter-free and one-write-per-owned-vertex: the paper's discipline, blocked.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .distributed import shard_map_loop
from .frontier import (FS_ACTIVE_ROWS, FS_COMPACT, FS_ITERS, FS_OVERFLOW,
                       fstats_init, publish_fstats, stream_compact)
from .graph import Graph
from .pagerank import PRParams
from .rank_step import rank_step
from ..obs.spans import get_registry as _obs
from ..obs.trace import trace_init, trace_record

__all__ = ["Sharded2D", "build_sharded_2d", "pagerank_2d", "dfp_2d"]


class Sharded2D(NamedTuple):
    """Per-device edge blocks, leading axis = r·c (row-major (i, j))."""
    ell_idx: jnp.ndarray    # [rc, V/c, d_p] int32 — LOCAL col ids into c_row
    ell_mask: jnp.ndarray   # [rc, V/c, d_p] f32
    out_deg: jnp.ndarray    # [rc, V/rc] int32 (owned vertices, b = i*c + j)
    valid: jnp.ndarray      # [rc, V/rc] bool
    n_true: int
    r: int
    c: int


def build_sharded_2d(g: Graph, r: int, c: int, d_p: int = 8) -> Sharded2D:
    """Host partitioner. Edge (u -> v) lands on device (u // (V/r) ...
    truncated to r rows, v-range analog for columns). Per-destination degree
    within one block is ~deg/r, so the block layout is pure ELL with a small
    d_p (overflow edges spill to extra ELL columns by raising d_p)."""
    assert r == c, "2-D scheme assumes a square (data, model) sub-mesh"
    n = g.n
    rc = r * c
    n_pad = ((n + rc - 1) // rc) * rc
    v_r = n_pad // r          # row/column range size
    blk = n_pad // rc

    # per-device ELL over destinations in range(j), sources in range(i)
    src, dst = g.edges()
    i_of = np.minimum(src // v_r, r - 1)
    j_of = np.minimum(dst // v_r, c - 1)
    dev = i_of * c + j_of
    order = np.argsort(dev, kind="stable")
    src, dst, dev = src[order], dst[order], dev[order]
    starts = np.searchsorted(dev, np.arange(rc))
    ends = np.searchsorted(dev, np.arange(rc) + 1)

    # find required d_p: max per-(device, destination) multiplicity
    need = 1
    for b in range(rc):
        s, e = starts[b], ends[b]
        if e > s:
            cnt = np.bincount(dst[s:e] - (dev[s:e] % c) * v_r,
                              minlength=v_r)
            need = max(need, int(cnt.max()))
    d_p = max(d_p, need)

    ell_idx = np.zeros((rc, v_r, d_p), np.int32)
    ell_mask = np.zeros((rc, v_r, d_p), np.float32)
    for b in range(rc):
        s, e = starts[b], ends[b]
        if e <= s:
            continue
        i, j = b // c, b % c
        ld = dst[s:e] - j * v_r          # local destination row
        ls = src[s:e] - i * v_r          # local source (col into c_row)
        o = np.argsort(ld, kind="stable")
        lds, lss = ld[o], ls[o]
        pos = np.arange(lds.size) - np.searchsorted(lds, lds, side="left")
        ell_idx[b, lds, pos] = lss
        ell_mask[b, lds, pos] = 1.0

    deg = np.ones((rc, blk), np.int32)
    valid = np.zeros((rc, blk), bool)
    od = g.out_degree()
    for b in range(rc):
        lo = b * blk
        hi = min((b + 1) * blk, n)
        if hi > lo:
            deg[b, :hi - lo] = od[lo:hi]
            valid[b, :hi - lo] = True
    return Sharded2D(ell_idx=jnp.asarray(ell_idx),
                     ell_mask=jnp.asarray(ell_mask),
                     out_deg=jnp.asarray(deg), valid=jnp.asarray(valid),
                     n_true=n, r=r, c=c)


def _loop_2d(params: PRParams, n_true: int, r: int, c: int, *, dfp: bool,
             row_axis="data", col_axis="model", trace: bool = False,
             row_cap: int | None = None):
    """Per-device while loop. Mesh axes: row_axis size r, col_axis size c.

    The per-iteration math is the shared `core.rank_step.rank_step` on the
    owned vertex block; this loop supplies only the blocked pull schedule
    (all-gather along the column axis, psum-scatter along the row axis,
    ppermute back to the owner — DESIGN.md §6). Frontier expansion runs at
    iteration 0 too, so δ_N may be seeded raw (paper's initial expansion,
    device-side) exactly as in the 1-D engine. ``trace`` carries an
    obs.trace.TraceBuffer; channels are psum'd over both mesh axes so the
    buffer is replicated (out_spec P()).

    ``row_cap`` (static) compacts the rank pull's destination loop: the
    mesh-row's δ_V slice is assembled by the same transpose-permute +
    row-axis all-gather the owned pieces use, stream-compacted into a
    [row_cap] active-destination list, and the edge-block gather-reduce runs
    over those rows only — per-device edge work O(row_cap · d_p) instead of
    O(V/r · d_p). Overflow falls back to the full block for that iteration
    (the cond's branches hold no collectives — the all-gather/psum-scatter/
    ppermute schedule stays outside, so divergence across devices is fine).
    The expansion pull stays full-width: its output IS the new frontier,
    which is exactly what is not yet known."""

    def loop(sgd, r0, dv0, dn0):
        ell_idx = sgd["ell_idx"][0]
        ell_mask = sgd["ell_mask"][0]
        out_deg = sgd["out_deg"][0]
        deg = out_deg.astype(r0.dtype)
        valid = sgd["valid"][0]
        rank0, dv0, dn0 = r0[0], dv0[0], dn0[0]
        dt = rank0.dtype
        v_r = ell_idx.shape[0]
        perm = [(a * c + b, b * c + a) for a in range(r) for b in range(c)]

        def pull(vec_own, sel=None, ovf=None):
            """vec_own [blk] -> per-destination sums [v_r] -> own piece."""
            # 1. gather this mesh-row's owned pieces = contiguous row range i
            v_row = jax.lax.all_gather(vec_own, col_axis, tiled=True)

            # 2. local masked gather-reduce over the edge block — all
            # destinations, or only the compacted active list
            def full_part():
                return jnp.sum(jnp.take(v_row, ell_idx, axis=0)
                               * ell_mask.astype(vec_own.dtype), axis=1)

            if sel is None:
                part = full_part()
            else:
                def active_part():
                    idx_s = jnp.take(ell_idx, sel, axis=0, mode="fill",
                                     fill_value=0)
                    msk_s = jnp.take(ell_mask, sel, axis=0, mode="fill",
                                     fill_value=0.0)
                    sums = jnp.sum(jnp.take(v_row, idx_s, axis=0)
                                   * msk_s.astype(vec_own.dtype), axis=1)
                    return jnp.zeros((v_r,), vec_own.dtype) \
                        .at[sel].add(sums, mode="drop")
                part = jax.lax.cond(ovf, full_part, active_part)
            # 3. reduce partials over mesh rows; keep piece i of range j
            piece = jax.lax.psum_scatter(part, row_axis, scatter_dimension=0,
                                         tiled=True)
            # 4. piece belongs to block (j, i) -> transpose devices
            return jax.lax.ppermute(piece, (row_axis, col_axis), perm)

        def dv_row_of(dv_own):
            """Owned δ_V pieces -> this mesh-row's destination-range slice:
            the transpose permute parks block j·c+i on device (i, j), so the
            row-axis gather concatenates blocks j·c+0 .. j·c+(r-1) — range j
            in vertex order (r == c)."""
            dvp = jax.lax.ppermute(dv_own.astype(jnp.uint8),
                                   (row_axis, col_axis), perm)
            return jax.lax.all_gather(dvp, row_axis, tiled=True) > 0

        def body(state):
            rank, dv, dn, _, it, tb, fs = state
            if dfp:
                grow = pull(dn.astype(dt)) > 0          # Σ>0 ⇔ OR
                dv = (dv | grow) & valid
            dv_in = dv & valid
            if row_cap is not None:
                sel, cnt = stream_compact(dv_row_of(dv_in), row_cap, v_r)
                ovf = cnt > row_cap
                s = pull(rank / deg, sel, ovf)
                ok = (~ovf).astype(jnp.int32)
                fs = fs.at[FS_ITERS].add(1).at[FS_COMPACT].add(ok) \
                       .at[FS_OVERFLOW].add(1 - ok) \
                       .at[FS_ACTIVE_ROWS].add(cnt * ok)
            else:
                s = pull(rank / deg)
            r_new, dv_new, dn_new, local = rank_step(
                s, rank, dv_in, out_deg, alpha=params.alpha,
                n_norm=n_true, tau_f=params.tau_f, tau_p=params.tau_p,
                prune=dfp, closed_form=dfp, track_frontier=dfp)
            if dfp:
                dv, dn = dv_new, dn_new
            delta = jax.lax.pmax(local, (row_axis, col_axis))
            if trace:
                counts = jnp.stack([
                    jnp.sum(dv_in), jnp.sum(dn_new),
                    jnp.sum(dv_in) - jnp.sum(dv_new & valid)]
                ).astype(jnp.int32)
                counts = jax.lax.psum(counts, (row_axis, col_axis))
                tb = trace_record(tb, it, linf=delta, frontier=counts[0],
                                  delta_n=counts[1] if dfp else 0,
                                  pruned=counts[2] if dfp else 0)
            return r_new, dv, dn, delta, it + 1, tb, fs

        def cond(state):
            delta, it = state[3], state[4]
            return (delta > params.tau) & (it < params.max_iter)

        tb0 = trace_init(params.max_iter, dt,
                         "dfp_2d" if dfp else "static_2d") if trace \
            else jnp.asarray(0, jnp.int32)
        init = (rank0, dv0, dn0, jnp.asarray(jnp.inf, dt),
                jnp.asarray(0, jnp.int32), tb0, fstats_init(0))
        rank, dv, dn, _, iters, tb, fs = jax.lax.while_loop(cond, body, init)
        out = [rank[None], iters]
        if trace:
            out.append(tb)
        if row_cap is not None:
            out.append(jax.lax.psum(fs, (row_axis, col_axis)))
        return tuple(out)

    return loop


def _run(mesh: Mesh, sg: Sharded2D, r0, dv0, dn0, params, dfp: bool,
         trace: bool = False, row_cap: int | None = None):
    axes = mesh.axis_names
    row_axis, col_axis = axes[-2], axes[-1]
    shard = P((row_axis, col_axis))
    sgd = {"ell_idx": sg.ell_idx, "ell_mask": sg.ell_mask,
           "out_deg": sg.out_deg, "valid": sg.valid}
    loop = _loop_2d(params, sg.n_true, sg.r, sg.c, dfp=dfp,
                    row_axis=row_axis, col_axis=col_axis, trace=trace,
                    row_cap=row_cap)
    out_specs = [shard, P()]
    if trace:
        out_specs.append(P())
    if row_cap is not None:
        out_specs.append(P())
    fn = shard_map_loop(loop, mesh,
                        ({k: shard for k in sgd}, shard, shard, shard),
                        tuple(out_specs))
    out = jax.jit(fn)(sgd, r0, dv0, dn0)
    if row_cap is not None:
        *out, fs = out
        publish_fstats(fs)
        out = tuple(out)
    return out


def pagerank_2d(mesh: Mesh, sg: Sharded2D, r0, params: PRParams = PRParams(),
                trace: bool = False):
    rc, blk = sg.out_deg.shape
    on = jnp.ones((rc, blk), jnp.bool_)
    off = jnp.zeros((rc, blk), jnp.bool_)
    with _obs().span("solve.static_2d", annotate=True):
        return _run(mesh, sg, r0, on, off, params, dfp=False, trace=trace)


def dfp_2d(mesh: Mesh, sg: Sharded2D, r_prev, dv0, dn0,
           params: PRParams = PRParams(), trace: bool = False,
           row_cap: int | None = None):
    """2-D DF-P. ``row_cap`` (static pow2) compacts each device's
    destination loop to its mesh-row's active δ_V rows — identical ranks,
    O(row_cap·d_p) local edge work, full-block fallback on overflow."""
    with _obs().span("solve.dfp_2d", annotate=True):
        return _run(mesh, sg, r_prev, dv0, dn0, params, dfp=True, trace=trace,
                    row_cap=row_cap)
