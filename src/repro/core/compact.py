"""Frontier-compacted DF / DF-P — the TPU translation of "skip unaffected
vertices".

The paper's update kernels do `if not δ_V[v]: continue`; a GPU thread that
skips costs nothing. Dense XLA arrays don't skip — a masked update still
pays the full |V|·d_p gather — which erases the paper's headline speedup.
This module restores it with static-shape *compaction*:

  * affected vertex ids are extracted with jnp.nonzero(size=K) (K is a
    static capacity, chosen per batch from the initial frontier size);
  * the rank pull gathers ONLY those K rows of the in-neighbor ELL (+ the
    affected high-in-degree tile subset), so per-iteration edge work is
    O(frontier · degree) like the paper's, not O(|E|);
  * frontier expansion mirrors the paper exactly: it walks the OUT-edges of
    flagged vertices (out-degree-partitioned forward layout) and scatters
    flags — work ∝ Σ out-degree(frontier), the same bound as Alg. 5;
  * if the frontier ever outgrows K, the loop exits and the dense engine
    (core/dynamic.py) finishes from the current state — correctness never
    depends on the capacity guess.

One write per affected vertex per iteration is preserved throughout.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .dynamic import DeviceBatch, _loop
from .frontier import expand_affected, initial_affected
from .graph import Graph, build_hybrid, next_pow2 as _next_pow2
from .pagerank import DeviceGraph, PRParams, as_device_graph, to_device
from .rank_step import rank_value, relative_change, teleport
from ..obs.trace import trace_init, trace_record

__all__ = ["forward_device_graph", "dfp_pagerank_compact",
           "df_pagerank_compact"]


def forward_device_graph(g: Graph, d_p: int = 64, tile: int = 1024,
                         **caps) -> DeviceGraph:
    """Out-edge hybrid layout (the paper's 'Partition G' by out-degree):
    rows of the ELL are each vertex's OUT-neighbors."""
    return to_device(build_hybrid(g.transpose(), d_p=d_p, tile=tile, **caps))


def _compact(flags: jnp.ndarray, k: int, fill: int) -> jnp.ndarray:
    return jnp.nonzero(flags, size=k, fill_value=fill)[0]


def _gather_pull(dg: DeviceGraph, c: jnp.ndarray, idx: jnp.ndarray,
                 tile_sel: jnp.ndarray) -> jnp.ndarray:
    """Pull contributions for the K vertices in `idx` only.

    ELL side: each compacted vertex's row lives in exactly one degree
    bucket; gather K slots per bucket (dead lanes hit the cap sentinel and
    read mask 0) and sum the per-bucket partials — every vertex picks up
    its value from its own bucket, zeros elsewhere. High side: `tile_sel`
    is a compacted list of tile ids whose owner vertex is affected; their
    sums are scattered into a dense [n]-buffer (cheap: K_t · tile work,
    one write per tile)."""
    dt = c.dtype
    nb = len(dg.buckets)
    b_of = jnp.take(dg.bucket_of, idx, mode="fill", fill_value=nb)
    s_of = jnp.take(dg.slot_of, idx, mode="fill", fill_value=0)
    low = jnp.zeros(idx.shape, dt)
    for bi, blk in enumerate(dg.buckets):
        slot = jnp.where(b_of == bi, s_of, blk.rows.shape[0])
        rows_idx = jnp.take(blk.idx, slot, axis=0, mode="fill", fill_value=0)
        rows_mask = jnp.take(blk.mask, slot, axis=0, mode="fill",
                             fill_value=0.0)
        low = low + jnp.sum(jnp.take(c, rows_idx, axis=0)
                            * rows_mask.astype(dt), axis=1)

    tiles = jnp.take(dg.hi_tiles, tile_sel, axis=0, mode="fill", fill_value=0)
    tmask = jnp.take(dg.hi_tmask, tile_sel, axis=0, mode="fill",
                     fill_value=0.0)
    tsums = jnp.sum(jnp.take(c, tiles, axis=0) * tmask.astype(dt), axis=1)
    slot = jnp.take(dg.hi_rowmap, tile_sel, mode="fill",
                    fill_value=dg.n_hi_cap - 1)
    owner = jnp.take(dg.hi_ids, slot)                    # vertex id or n
    hi_dense = jnp.zeros((dg.n + 1,), dt).at[owner].add(tsums, mode="drop")
    return low + jnp.take(hi_dense, jnp.minimum(idx, dg.n), axis=0) \
        * (idx < dg.n)


def _scatter_expand(fwd: DeviceGraph, dn_flags: jnp.ndarray, kn: int
                    ) -> jnp.ndarray:
    """Paper Alg. 5 expandAffected, compacted: out-neighbors of flagged
    vertices get marked. Returns a dense bool [n] of newly-marked vertices."""
    n = fwd.n
    src = _compact(dn_flags, kn, n)
    nb = len(fwd.buckets)
    b_of = jnp.take(fwd.bucket_of, src, mode="fill", fill_value=nb)
    s_of = jnp.take(fwd.slot_of, src, mode="fill", fill_value=0)
    out = jnp.zeros((n + 1,), jnp.bool_)
    for bi, blk in enumerate(fwd.buckets):
        slot = jnp.where(b_of == bi, s_of, blk.rows.shape[0])
        nbr = jnp.take(blk.idx, slot, axis=0, mode="fill", fill_value=0)
        msk = jnp.take(blk.mask, slot, axis=0, mode="fill", fill_value=0.0)
        tgt = jnp.where(msk > 0, nbr, n)
        out = out.at[tgt.reshape(-1)].set(True, mode="drop")
    # high-out-degree frontier vertices: walk their tile lists
    hi_aff = jnp.take(dn_flags, jnp.minimum(fwd.hi_ids, n - 1),
                      mode="fill", fill_value=False) & (fwd.hi_ids < n)
    tile_on = jnp.take(hi_aff, fwd.hi_rowmap)
    tgt2 = jnp.where((fwd.hi_tmask > 0) & tile_on[:, None], fwd.hi_tiles, n)
    out = out.at[tgt2.reshape(-1)].set(True, mode="drop")
    return out[:n]


def _tiles_for(dg: DeviceGraph, dv: jnp.ndarray, kt: int):
    """Compacted ids of high-in-degree tiles whose owner is affected.
    Returns (tile_sel, n_needed) — callers must treat n_needed > kt as a
    capacity overflow (silent truncation would corrupt hub ranks)."""
    n = dg.n
    owner_aff = jnp.take(dv, jnp.minimum(dg.hi_ids, n - 1),
                         mode="fill", fill_value=False) & (dg.hi_ids < n)
    tile_on = jnp.take(owner_aff, dg.hi_rowmap)
    return _compact(tile_on, kt, dg.hi_tiles.shape[0]), jnp.sum(tile_on)


@functools.partial(jax.jit,
                   static_argnames=("params", "k", "kt", "kn", "prune",
                                    "trace"))
def _compact_loop(dg: DeviceGraph, fwd: DeviceGraph, r0, dv0, dn0,
                  params: PRParams, k: int, kt: int, kn: int, prune: bool,
                  trace: bool = False):
    n = dg.n
    dt = r0.dtype
    d = dg.out_deg.astype(dt)
    c0 = teleport(params.alpha, n, dt)

    def body(state):
        r, dv, dn, _, i, tb = state
        dv = jnp.where(i > 0, dv | _scatter_expand(fwd, dn, kn), dv)
        dv_in = dv   # post-expansion frontier entering this sweep (trace)
        tsel, n_tiles = _tiles_for(dg, dv, kt)
        overflow = (jnp.sum(dv) > k) | (jnp.sum(dn) > kn) | (n_tiles > kt)
        idx = _compact(dv, k, n)
        c = r / d
        s = _gather_pull(dg, c, idx, tsel)
        r_i = jnp.take(r, jnp.minimum(idx, n - 1))
        d_i = jnp.take(d, jnp.minimum(idx, n - 1))
        # the compact binding of the shared Eq. 1/Eq. 2 math (core.rank_step):
        # dead lanes (idx == n) evaluate against r_i so dr/rel read 0 there
        rv = rank_value(s, r_i, d_i, alpha=params.alpha, c0=c0,
                        closed_form=prune)
        live = idx < n
        dr, rel = relative_change(jnp.where(live, rv, r_i), r_i, floor=1e-300)
        rv = jnp.where(live, rv, 0.0)
        r_new = r.at[idx].set(rv, mode="drop")
        if prune:
            keep = live & ~(rel <= params.tau_p)
            dv = dv.at[idx].set(False, mode="drop")
            dv = dv.at[jnp.where(keep, idx, n)].set(True, mode="drop")
        dn_new = jnp.zeros((n,), jnp.bool_).at[
            jnp.where(live & (rel > params.tau_f), idx, n)].set(
            True, mode="drop")
        # an overflowing iteration must not commit a truncated update: keep
        # the pre-iteration state and exit with delta=inf (dense fallback)
        r_new = jnp.where(overflow, r, r_new)
        dv = jnp.where(overflow, state[1], dv)
        dn_new = jnp.where(overflow, dn, dn_new)
        delta = jnp.where(overflow, jnp.asarray(jnp.inf, dt), jnp.max(dr))
        if trace:
            # the overflow iteration records linf=inf — the visible marker
            # of the dense handoff
            frontier = jnp.sum(dv_in)
            tb = trace_record(
                tb, i, linf=delta, frontier=frontier,
                delta_n=jnp.sum(dn_new),
                pruned=frontier - jnp.sum(dv) if prune else 0)
        return r_new, dv, dn_new, delta, i + 1, tb

    def cond(state):
        r, dv, dn, delta, i, _ = state
        within = (jnp.sum(dv) <= k) & (jnp.sum(dn) <= kn)
        return (delta > params.tau) & (i < params.max_iter) & within \
            & ~jnp.isinf(delta)
    # NOTE: body sets delta=inf on any capacity overflow (incl. tile list),
    # so an exit through `within` always routes to the dense fallback.

    tb0 = trace_init(params.max_iter, dt,
                     "dfp_compact" if prune else "df_compact") if trace \
        else jnp.asarray(0, jnp.int32)
    # finite sentinel: inf is reserved for the capacity-overflow signal
    init = (r0, dv0, dn0, jnp.asarray(jnp.finfo(dt).max, dt),
            jnp.asarray(0, jnp.int32), tb0)
    r, dv, dn, delta, iters, tb = jax.lax.while_loop(cond, body, init)
    return r, dv, dn, delta, iters, (tb if trace else None)


def _df_like_compact(dg, fwd, r_prev, batch: DeviceBatch,
                     params: PRParams, *, prune: bool, headroom: int = 16,
                     trace: bool = False):
    n = dg.n
    dv, dn = initial_affected(n, batch.del_src, batch.del_dst, batch.ins_src)
    # initial marking via the compacted out-edge walk (paper Alg. 5), not a
    # dense O(|E|) pull — the batch is tiny relative to the graph
    kn_init = min(_next_pow2(int(jnp.sum(dn)) * 2 + 2), n)
    dv = dv | _scatter_expand(fwd, dn, kn_init)
    n_init = int(jnp.sum(dv)) + 1
    k = min(_next_pow2(n_init * headroom), n)
    kn = k
    # No tile compaction: affected hubs legitimately need their full tile
    # lists, and the high side is a small fraction of total edge slots —
    # the ELL (low-degree majority) is where compaction pays (tile
    # truncation forced immediate dense fallback on power-law graphs,
    # refuting the tile-compaction hypothesis — DESIGN.md §4).
    kt = dg.hi_tiles.shape[0]
    dn0 = jnp.zeros((n,), jnp.bool_)
    r, dv, dn, delta, iters, tb = _compact_loop(dg, fwd, r_prev, dv, dn0,
                                                params, k, kt, kn, prune,
                                                trace)
    if float(delta) > params.tau and int(iters) < params.max_iter:
        # frontier outgrew the capacity: dense engine finishes the job,
        # appending to the same trace buffer at offset `iters`
        rest = params._replace(max_iter=params.max_iter - int(iters))
        out = _dense_finish(dg, r, dv, dn, rest, prune, tb,
                            jnp.asarray(int(iters), jnp.int32))
        r, it2, tb = out if trace else (*out, None)
        iters = iters + it2
    return (r, iters, tb) if trace else (r, iters)


@functools.partial(jax.jit, static_argnames=("params", "prune"))
def _dense_finish(dg, r, dv, dn, params, prune, tb=None, i_off=0):
    return _loop(dg, r, dv, dn, params, expand=True, prune=prune,
                 closed_form=prune, tb=tb, i_off=i_off)


def _stage_pair(dg, fwd):
    """Resolve (pull, forward) device graphs; a pre-staged snapshot exposing
    `.dg`/`.fwd_dg` (repro.stream.DeviceSnapshot) may be passed as `dg` with
    fwd=None and supplies both orientations."""
    if fwd is None:
        fwd = getattr(dg, "fwd_dg", None)
        if fwd is None:
            raise TypeError("fwd is required unless dg is a snapshot "
                            "exposing .fwd_dg")
    return as_device_graph(dg), as_device_graph(fwd)


def dfp_pagerank_compact(dg, fwd=None, r_prev=None,
                         batch: DeviceBatch = None,
                         params: PRParams = PRParams(),
                         trace: bool = False):
    dg, fwd = _stage_pair(dg, fwd)
    return _df_like_compact(dg, fwd, r_prev, batch, params, prune=True,
                            trace=trace)


def df_pagerank_compact(dg, fwd=None, r_prev=None,
                        batch: DeviceBatch = None,
                        params: PRParams = PRParams(),
                        trace: bool = False):
    dg, fwd = _stage_pair(dg, fwd)
    return _df_like_compact(dg, fwd, r_prev, batch, params, prune=False,
                            trace=trace)
