"""Frontier-compacted DF / DF-P — the TPU translation of "skip unaffected
vertices".

The paper's update kernels do `if not δ_V[v]: continue`; a GPU thread that
skips costs nothing. Dense XLA arrays don't skip — a masked update still
pays the full |V|·d_p gather — which erases the paper's headline speedup.
This module restores it with static-shape *compaction*:

  * affected vertex ids are extracted with jnp.nonzero(size=K) (K is a
    static capacity, chosen per batch from the initial frontier size);
  * the rank pull gathers ONLY those K rows of the in-neighbor ELL (+ the
    affected high-in-degree tile subset), so per-iteration edge work is
    O(frontier · degree) like the paper's, not O(|E|);
  * frontier expansion mirrors the paper exactly: it walks the OUT-edges of
    flagged vertices (out-degree-partitioned forward layout) and scatters
    flags — work ∝ Σ out-degree(frontier), the same bound as Alg. 5;
  * if the frontier ever outgrows K, the loop exits and the dense engine
    (core/dynamic.py) finishes from the current state — correctness never
    depends on the capacity guess.

One write per affected vertex per iteration is preserved throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dynamic import DeviceBatch, _loop, solve_health
from .frontier import (FrontierCaps, active_frontier, initial_affected,
                       plan_capacity, push_expand, update_ranks_active)
from .graph import Graph, build_hybrid
from .pagerank import DeviceGraph, PRParams, as_device_graph, to_device
from ..obs.spans import get_registry as _obs
from ..obs.trace import trace_init, trace_record

__all__ = ["forward_device_graph", "dfp_pagerank_compact",
           "df_pagerank_compact"]


def forward_device_graph(g: Graph, d_p: int = 64, tile: int = 1024,
                         **caps) -> DeviceGraph:
    """Out-edge hybrid layout (the paper's 'Partition G' by out-degree):
    rows of the ELL are each vertex's OUT-neighbors."""
    return to_device(build_hybrid(g.transpose(), d_p=d_p, tile=tile, **caps))


def _scatter_expand(fwd: DeviceGraph, dn_flags: jnp.ndarray, kn: int
                    ) -> jnp.ndarray:
    """Paper Alg. 5 expandAffected, compacted (core.frontier.push_expand):
    out-neighbors of flagged vertices get marked. Returns a dense bool [n]
    of newly-marked vertices (complete only while Σδ_N ≤ kn)."""
    return push_expand(fwd, dn_flags, kn)[0]


@functools.partial(jax.jit,
                   static_argnames=("params", "k", "kt", "kn", "prune",
                                    "trace"))
def _compact_loop(dg: DeviceGraph, fwd: DeviceGraph, r0, dv0, dn0,
                  params: PRParams, k: int, kt: int, kn: int, prune: bool,
                  trace: bool = False):
    n = dg.n
    dt = r0.dtype
    # the engine's (K, K_t, K_n) sizing expressed on the shared capacity
    # plan: per-bucket lists are K clamped to each bucket's slot count, the
    # total-rows budget K is enforced separately below (this engine *exits*
    # to the dense driver on overflow rather than paying full sweeps, so an
    # oversized total frontier must still trip it even when every
    # per-bucket list individually fits)
    caps = FrontierCaps(
        bucket=tuple(min(k, int(b.rows.shape[0])) for b in dg.buckets),
        hi=min(k, dg.n_hi_cap), tiles=kt, dn=kn, fwd_tiles=0)

    def body(state):
        r, dv, dn, _, i, tb = state
        marks, push_ovf = push_expand(fwd, dn, kn)
        dv = jnp.where(i > 0, dv | marks, dv)
        dv_in = dv   # post-expansion frontier entering this sweep (trace)
        af = active_frontier(dg.buckets, dg.hi_ids, dg.hi_rowmap, dv, caps)
        overflow = af.overflow | push_ovf | (af.n_rows > k)
        r_new, dv_new, dn_new, dmax = update_ranks_active(
            dg, r, dv, af, alpha=params.alpha, tau_f=params.tau_f,
            tau_p=params.tau_p, prune=prune, closed_form=prune,
            track_frontier=True)
        # an overflowing iteration must not commit a truncated update: keep
        # the pre-iteration state and exit with delta=inf (dense fallback)
        r_new = jnp.where(overflow, r, r_new)
        dv = jnp.where(overflow, dv_in, dv_new)
        dn_new = jnp.where(overflow, dn, dn_new)
        delta = jnp.where(overflow, jnp.asarray(jnp.inf, dt), dmax)
        if trace:
            # the overflow iteration records linf=inf — the visible marker
            # of the dense handoff. Frontier-size reductions live only on
            # this traced path; the untraced loop computes none.
            frontier = jnp.sum(dv_in)
            tb = trace_record(
                tb, i, linf=delta, frontier=frontier,
                delta_n=jnp.sum(dn_new),
                pruned=frontier - jnp.sum(dv) if prune else 0)
        return r_new, dv, dn_new, delta, i + 1, tb

    def cond(state):
        delta, i = state[3], state[4]
        return (delta > params.tau) & (i < params.max_iter) \
            & ~jnp.isinf(delta)
    # NOTE: body sets delta=inf on ANY capacity overflow (row, tile or
    # worklist), and an overflowing body commits nothing — so the inf check
    # alone routes every overflow to the dense fallback; the old per-cond
    # Σδ_V / Σδ_N reductions were dead work and are gone.

    tb0 = trace_init(params.max_iter, dt,
                     "dfp_compact" if prune else "df_compact") if trace \
        else jnp.asarray(0, jnp.int32)
    # finite sentinel: inf is reserved for the capacity-overflow signal
    init = (r0, dv0, dn0, jnp.asarray(jnp.finfo(dt).max, dt),
            jnp.asarray(0, jnp.int32), tb0)
    r, dv, dn, delta, iters, tb = jax.lax.while_loop(cond, body, init)
    return r, dv, dn, delta, iters, (tb if trace else None)


def _df_like_compact(dg, fwd, r_prev, batch: DeviceBatch,
                     params: PRParams, *, prune: bool, headroom: int = 16,
                     trace: bool = False, health: bool = False):
    n = dg.n
    dv, dn = initial_affected(n, batch.del_src, batch.del_dst, batch.ins_src)
    # initial marking via the compacted out-edge walk (paper Alg. 5), not a
    # dense O(|E|) pull — the batch is tiny relative to the graph
    kn_init = plan_capacity(int(jnp.sum(dn)) + 1, n, headroom=2)
    dv = dv | _scatter_expand(fwd, dn, kn_init)
    n_init = int(jnp.sum(dv)) + 1
    k = plan_capacity(n_init, n, headroom=headroom)
    kn = k
    # No tile compaction: affected hubs legitimately need their full tile
    # lists, and the high side is a small fraction of total edge slots —
    # the ELL (low-degree majority) is where compaction pays (tile
    # truncation forced immediate dense fallback on power-law graphs,
    # refuting the tile-compaction hypothesis — DESIGN.md §4).
    kt = dg.hi_tiles.shape[0]
    dn0 = jnp.zeros((n,), jnp.bool_)
    r, dv, dn, delta, iters, tb = _compact_loop(dg, fwd, r_prev, dv, dn0,
                                                params, k, kt, kn, prune,
                                                trace)
    hw = None
    if float(delta) > params.tau and int(iters) < params.max_iter:
        # frontier outgrew the capacity: dense engine finishes the job,
        # appending to the same trace buffer at offset `iters`. Its health
        # word (budget = the REMAINING iterations) is the solve's health
        # word: exhausting `rest` is exactly exhausting the total budget.
        rest = params._replace(max_iter=params.max_iter - int(iters))
        out = list(_dense_finish(dg, r, dv, dn, rest, prune, tb,
                                 jnp.asarray(int(iters), jnp.int32), health))
        if health:
            hw = out.pop()
        r, it2 = out[0], out[1]
        tb = out[2] if trace else None
        iters = iters + it2
    elif health:
        hw = solve_health(delta, iters, jnp.sum(r), params)
    res = [r, iters]
    if trace:
        res.append(tb)
    if health:
        res.append(hw)
    return tuple(res) if trace or health else (r, iters)


@functools.partial(jax.jit, static_argnames=("params", "prune", "health"))
def _dense_finish(dg, r, dv, dn, params, prune, tb=None, i_off=0,
                  health: bool = False):
    return _loop(dg, r, dv, dn, params, expand=True, prune=prune,
                 closed_form=prune, tb=tb, i_off=i_off, health=health)


def _stage_pair(dg, fwd):
    """Resolve (pull, forward) device graphs; a pre-staged snapshot exposing
    `.dg`/`.fwd_dg` (repro.stream.DeviceSnapshot) may be passed as `dg` with
    fwd=None and supplies both orientations."""
    if fwd is None:
        fwd = getattr(dg, "fwd_dg", None)
        if fwd is None:
            raise TypeError("fwd is required unless dg is a snapshot "
                            "exposing .fwd_dg")
    return as_device_graph(dg), as_device_graph(fwd)


def dfp_pagerank_compact(dg, fwd=None, r_prev=None,
                         batch: DeviceBatch = None,
                         params: PRParams = PRParams(),
                         trace: bool = False, health: bool = False):
    with _obs().span("solve.dfp_compact", annotate=True):
        dg, fwd = _stage_pair(dg, fwd)
        return _df_like_compact(dg, fwd, r_prev, batch, params, prune=True,
                                trace=trace, health=health)


def df_pagerank_compact(dg, fwd=None, r_prev=None,
                        batch: DeviceBatch = None,
                        params: PRParams = PRParams(),
                        trace: bool = False, health: bool = False):
    with _obs().span("solve.df_compact", annotate=True):
        dg, fwd = _stage_pair(dg, fwd)
        return _df_like_compact(dg, fwd, r_prev, batch, params, prune=False,
                                trace=trace, health=health)
