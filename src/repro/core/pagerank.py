"""Static PageRank (paper Alg. 1) — synchronous, pull-based, scatter-free.

The device graph is the hybrid ELL + tiled-CSR layout of the *transpose* graph
(see core/graph.py). Rank computation is one gather-reduce per iteration with a
single masked write per vertex — the TPU translation of the paper's
atomics-free pull kernels. Low in-degree vertices ride the ELL (lane-per-vertex)
path; high in-degree vertices ride the tiled-CSR (tile-loop-per-vertex) path,
combined with a segment-sum that plays the role of the block reduction.

`update_ranks` is shared verbatim between Static / ND / DT / DF / DF-P (the
paper re-uses `updateRanks()` the same way, toggling the affected flags).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, HybridLayout, build_hybrid
from .rank_step import rank_step
from ..obs.spans import get_registry as _obs
from ..obs.trace import trace_init, trace_record

__all__ = [
    "EllBlock", "DeviceGraph", "to_device", "as_device_graph", "pull_sum",
    "pull_max", "update_ranks", "static_pagerank", "PRParams", "init_ranks",
]

ALPHA = 0.85
TAU = 1e-10
TAU_F = 1e-6
TAU_P = 1e-6
MAX_ITER = 500


class EllBlock(NamedTuple):
    """One degree bucket of the low side, staged on device."""
    rows: jnp.ndarray       # [cap_b] int32 (sentinel = n)
    idx: jnp.ndarray        # [cap_b, w_b] int32
    mask: jnp.ndarray       # [cap_b, w_b] f32

    @property
    def width(self) -> int:
        return self.idx.shape[1]


class DeviceGraph(NamedTuple):
    """Hybrid bucketed pull layout staged on device (all jnp arrays,
    static shapes; the bucket tuple is static pytree structure)."""
    buckets: Tuple[EllBlock, ...]   # degree buckets, ascending width
    bucket_of: jnp.ndarray  # [n] int32 (len(buckets) = CSR side)
    slot_of: jnp.ndarray    # [n] int32 (slot within bucket / hi side)
    hi_ids: jnp.ndarray     # [n_hi_cap] int32 (sentinel = n)
    hi_tiles: jnp.ndarray   # [t_cap, tile] int32
    hi_tmask: jnp.ndarray   # [t_cap, tile] f32
    hi_rowmap: jnp.ndarray  # [t_cap] int32
    is_low: jnp.ndarray     # [n] bool
    out_deg: jnp.ndarray    # [n] int32 (>=1: self-loops guaranteed)

    @property
    def n(self) -> int:
        return self.is_low.shape[0]

    @property
    def n_hi_cap(self) -> int:
        return self.hi_ids.shape[0]


class PRParams(NamedTuple):
    alpha: float = ALPHA
    tau: float = TAU
    tau_f: float = TAU_F
    tau_p: float = TAU_P
    max_iter: int = MAX_ITER


def to_device(layout: HybridLayout) -> DeviceGraph:
    return DeviceGraph(
        buckets=tuple(EllBlock(rows=jnp.asarray(b.rows),
                               idx=jnp.asarray(b.idx),
                               mask=jnp.asarray(b.mask))
                      for b in layout.buckets),
        bucket_of=jnp.asarray(layout.bucket_of),
        slot_of=jnp.asarray(layout.slot_of),
        hi_ids=jnp.asarray(layout.hi_ids),
        hi_tiles=jnp.asarray(layout.hi_tiles),
        hi_tmask=jnp.asarray(layout.hi_tmask),
        hi_rowmap=jnp.asarray(layout.hi_rowmap),
        is_low=jnp.asarray(layout.is_low),
        out_deg=jnp.asarray(layout.out_deg),
    )


def device_graph(g: Graph, d_p: int = 64, tile: int = 1024, **caps) -> DeviceGraph:
    return to_device(build_hybrid(g, d_p=d_p, tile=tile, **caps))


def as_device_graph(obj) -> DeviceGraph:
    """Coerce to a pull-side DeviceGraph.

    Accepts a DeviceGraph (identity), any pre-staged snapshot exposing `.dg`
    (e.g. `repro.stream.DeviceSnapshot`), a host HybridLayout, or a Graph.
    Drivers call this outside their jitted impls so snapshots can be passed
    directly without retracing on the wrapper object.
    """
    if isinstance(obj, DeviceGraph):
        return obj
    staged = getattr(obj, "dg", None)
    if staged is not None:
        return staged
    if isinstance(obj, HybridLayout):
        return to_device(obj)
    if isinstance(obj, Graph):
        return device_graph(obj)
    raise TypeError(f"cannot stage {type(obj).__name__} as a DeviceGraph")


def init_ranks(n: int, dtype=jnp.float64) -> jnp.ndarray:
    dtype = jnp.zeros(0, dtype).dtype  # canonicalize under x64-disabled
    return jnp.full((n,), 1.0 / n, dtype=dtype)


# ---------------------------------------------------------------------------
# Pull primitives (single gather-reduce; one write per vertex)
# ---------------------------------------------------------------------------

def pull_sum(dg: DeviceGraph, c: jnp.ndarray) -> jnp.ndarray:
    """sum_{u in G'.row(v)} c[u] for every v — the paper's two rank kernels.

    ELL side: per degree bucket, [cap_b, w_b] masked gather + row-sum
    (lane-per-vertex at the bucket's width), scattered once through the
    bucket's row map. CSR side: [t_cap, tile] masked gather + tile-sum +
    segment-sum over the tile->row map (tile-loop-per-vertex with an
    on-chip accumulator on TPU), scattered once into the dense result
    (drop-mode handles pad sentinels on both sides).
    """
    dt = c.dtype
    out = jnp.zeros(c.shape, dt)
    for blk in dg.buckets:
        sums = jnp.sum(jnp.take(c, blk.idx, axis=0) * blk.mask.astype(dt),
                       axis=1)
        out = out.at[blk.rows].add(sums, mode="drop")
    tile_sums = jnp.sum(jnp.take(c, dg.hi_tiles, axis=0) * dg.hi_tmask.astype(dt), axis=1)
    hi_per_slot = jax.ops.segment_sum(tile_sums, dg.hi_rowmap,
                                      num_segments=dg.n_hi_cap)
    out = out.at[dg.hi_ids].add(hi_per_slot, mode="drop")
    return out


def pull_max(dg: DeviceGraph, x: jnp.ndarray) -> jnp.ndarray:
    """max_{u in G'.row(v)} x[u] — pull-based frontier expansion primitive.

    Replaces the paper's scatter-based `expandAffected` kernel pair (TPU has no
    cheap scatter); same fixpoint, same schedule, scatter-free.
    """
    dt = x.dtype
    out = jnp.zeros(x.shape, dt)
    for blk in dg.buckets:
        rmax = jnp.max(jnp.take(x, blk.idx, axis=0) * blk.mask.astype(dt),
                       axis=1, initial=0)
        out = out.at[blk.rows].max(rmax, mode="drop")
    tile_max = jnp.max(jnp.take(x, dg.hi_tiles, axis=0)
                       * dg.hi_tmask.astype(dt), axis=1, initial=0)
    hi_per_slot = jax.ops.segment_max(tile_max, dg.hi_rowmap,
                                      num_segments=dg.n_hi_cap)
    hi_per_slot = jnp.maximum(hi_per_slot, 0)  # empty segments -> -inf guard
    out = out.at[dg.hi_ids].max(hi_per_slot, mode="drop")
    return out


# ---------------------------------------------------------------------------
# updateRanks (paper Alg. 3) — shared across all five approaches
# ---------------------------------------------------------------------------

def update_ranks(dg: DeviceGraph, r: jnp.ndarray, affected: jnp.ndarray,
                 *, alpha: float, tau_f: float, tau_p: float,
                 prune: bool, closed_form: bool, track_frontier: bool,
                 pull_sum_fn=None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One synchronous rank sweep.

    Returns (r_new, affected', delta_N, linf_delta). With `affected` all-True,
    `prune=False`, `closed_form=False`, `track_frontier=False` this *is* the
    static kernel (paper: "disable the affected flags to utilize the same
    function for Static PageRank").

    This is the dense-engine binding of `core.rank_step.rank_step` — the
    repo-wide single implementation of the Eq. 1/Eq. 2 math — to the hybrid
    pull primitive above.
    """
    psum = pull_sum_fn or pull_sum
    s = psum(dg, r / dg.out_deg.astype(r.dtype))
    return rank_step(s, r, affected, dg.out_deg, alpha=alpha, n_norm=dg.n,
                     tau_f=tau_f, tau_p=tau_p, prune=prune,
                     closed_form=closed_form, track_frontier=track_frontier)


# ---------------------------------------------------------------------------
# Static PageRank driver (paper Alg. 1)
# ---------------------------------------------------------------------------

def static_pagerank(dg, r0: jnp.ndarray, params: PRParams = PRParams(),
                    pull_sum_fn=None, trace: bool = False,
                    health: bool = False):
    """Power iteration to L-inf tolerance. Returns (ranks, n_iters) — or
    (ranks, n_iters, TraceBuffer) with ``trace=True``, which carries the
    per-iteration L∞ series through the loop as aux state (obs.trace;
    identical ranks either way, no host callbacks). ``health=True`` appends
    the solve's guard.health word (int32 bitmask) last.

    `dg` may be a DeviceGraph or any pre-staged snapshot (see as_device_graph).
    """
    # every engine entry point dispatches under an annotated solve.* span,
    # so kernels land on the device timeline whenever a profiler trace is
    # live (ISSUE 10; the span itself times host dispatch only)
    with _obs().span("solve.static", annotate=True):
        return _static_pagerank(as_device_graph(dg), jnp.asarray(r0), params,
                                pull_sum_fn, trace, health)


@functools.partial(jax.jit, static_argnames=("params", "pull_sum_fn",
                                             "trace", "health"))
def _static_pagerank(dg: DeviceGraph, r0: jnp.ndarray,
                     params: PRParams = PRParams(),
                     pull_sum_fn=None, trace: bool = False,
                     health: bool = False):
    n = dg.n
    all_on = jnp.ones((n,), dtype=jnp.bool_)
    zero = jnp.asarray(0, jnp.int32)

    def body(state):
        r, _, i, tb = state
        r_new, _, _, delta = update_ranks(
            dg, r, all_on, alpha=params.alpha, tau_f=params.tau_f,
            tau_p=params.tau_p, prune=False, closed_form=False,
            track_frontier=False, pull_sum_fn=pull_sum_fn)
        if trace:
            tb = trace_record(tb, i, linf=delta, frontier=n, delta_n=0,
                              pruned=0)
        return r_new, delta, i + 1, tb

    def cond(state):
        _, delta, i, _ = state
        return (delta > params.tau) & (i < params.max_iter)

    tb0 = trace_init(params.max_iter, r0.dtype, "static") if trace else zero
    init = (r0, jnp.asarray(jnp.inf, r0.dtype), zero, tb0)
    r, delta, iters, tb = jax.lax.while_loop(cond, body, init)
    out = [r, iters]
    if trace:
        out.append(tb)
    if health:
        from ..guard.health import health_word, rank_mass  # lazy: no cycle
        dt = jnp.asarray(delta).dtype
        delta = jnp.where(jnp.isposinf(delta), jnp.finfo(dt).max, delta)
        out.append(health_word(delta, iters, rank_mass(r), tau=params.tau,
                               max_iter=params.max_iter))
    return tuple(out)
