"""Core library: the paper's contribution (Static + DF/DF-P PageRank) in JAX."""
from .graph import (Graph, HybridLayout, HybridRows, BatchUpdate, EllBucket,
                    build_graph, build_hybrid, build_hybrid_rows,
                    bucket_band_counts, choose_bucket_widths,
                    layout_slot_stats,
                    apply_batch, random_graph, powerlaw_graph, random_batch,
                    temporal_stream, edge_keys, keys_to_edges,
                    ragged_positions, hybrid_caps, graph_from_sorted_keys)
from .partition import partition_by_degree, partition_by_degree_jax
from .rank_step import rank_step, rank_value, relative_change, teleport
from .pagerank import (DeviceGraph, EllBlock, PRParams, to_device,
                       device_graph, as_device_graph, init_ranks, pull_sum,
                       pull_max, update_ranks, static_pagerank)
from .frontier import (initial_affected, expand_affected, reach_affected,
                       ActiveFrontier, FrontierCaps, active_frontier,
                       active_pull_sum, caps_for, caps_for_parts, merge_caps,
                       plan_capacity, push_expand, expand_frontier,
                       stream_compact, update_ranks_active)
from .dynamic import (DeviceBatch, batch_to_device, nd_pagerank, dt_pagerank,
                      df_pagerank, dfp_pagerank)
from .compact import (forward_device_graph, dfp_pagerank_compact,
                      df_pagerank_compact)
from .reference import reference_pagerank, numpy_pagerank, l1_error

__all__ = [
    "Graph", "HybridLayout", "HybridRows", "BatchUpdate", "EllBucket",
    "build_graph", "build_hybrid", "build_hybrid_rows",
    "bucket_band_counts", "choose_bucket_widths", "layout_slot_stats",
    "apply_batch", "random_graph", "powerlaw_graph", "random_batch",
    "temporal_stream", "edge_keys", "keys_to_edges", "ragged_positions",
    "hybrid_caps", "graph_from_sorted_keys",
    "partition_by_degree", "partition_by_degree_jax",
    "rank_step", "rank_value", "relative_change", "teleport",
    "DeviceGraph", "PRParams", "to_device", "device_graph", "as_device_graph",
    "EllBlock",
    "init_ranks", "pull_sum", "pull_max", "update_ranks", "static_pagerank",
    "initial_affected", "expand_affected", "reach_affected",
    "ActiveFrontier", "FrontierCaps", "active_frontier", "active_pull_sum",
    "caps_for", "caps_for_parts", "merge_caps", "plan_capacity",
    "push_expand", "expand_frontier", "stream_compact",
    "update_ranks_active",
    "DeviceBatch", "batch_to_device", "nd_pagerank", "dt_pagerank",
    "df_pagerank", "dfp_pagerank",
    "forward_device_graph", "dfp_pagerank_compact", "df_pagerank_compact",
    "reference_pagerank", "numpy_pagerank", "l1_error",
]
