"""The single shared ``updateRanks`` math (paper Alg. 3, Eq. 1 / Eq. 2).

The paper is explicit that one ``updateRanks()`` serves Static, ND, DT, DF
and DF-P alike ("disable the affected flags to utilize the same function for
Static PageRank"); this module is that single source of truth for the repo.
Every engine — dense (`core/pagerank.py` / `core/dynamic.py`), compact
(`core/compact.py`), 1-D sharded (`core/distributed.py`), 2-D sharded
(`core/distributed2d.py`) and the fused Pallas kernel
(`kernels/pr_update.py`) — imports the formulas from here and supplies only
its own *pull* (how the in-neighbor sums `s` are produced) and its own
plumbing (all-gather / psum-scatter / frontier compaction) around them.

The math itself, per vertex v with pulled contribution s = Σ R[u]/|out(u)|:

  Eq. 1 (plain):        R'[v] = (1-α)/N + α·s
  Eq. 2 (closed form):  R'[v] = ((1-α)/N + α·(s - R[v]/d_v)) / (1 - α/d_v)
                        — absorbs the guaranteed self-loop analytically.
  prune:   affected'[v] = affected[v] ∧ ¬(Δr/max(R,R') ≤ τ_p)
  δ_N:     rel > τ_f   (rel is 0 for unaffected vertices: R' == R there)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["teleport", "rank_value", "relative_change", "rank_step"]


def teleport(alpha: float, n_norm: int, dtype) -> jnp.ndarray:
    """The (1-α)/N teleport constant, canonicalized to the rank dtype.

    `n_norm` is the number of *real* vertices — sharded layouts pad |V| and
    must normalize by the true count, not the padded one.
    """
    return jnp.asarray((1.0 - alpha) / n_norm, dtype)


def rank_value(s: jnp.ndarray, r: jnp.ndarray, d: jnp.ndarray, *,
               alpha: float, c0: jnp.ndarray,
               closed_form: bool) -> jnp.ndarray:
    """Candidate new rank from the pulled in-neighbor sum `s`.

    `d` is the out-degree (≥ 1: self-loops are guaranteed), already in the
    rank dtype. `closed_form` selects Eq. 2 over Eq. 1. Shapes are whatever
    the caller gathered — dense [n], a compacted [K], or a per-shard slice.
    """
    if closed_form:
        return (c0 + alpha * (s - r / d)) / (1.0 - alpha / d)
    return c0 + alpha * s


def relative_change(r_new: jnp.ndarray, r_old: jnp.ndarray,
                    floor: Optional[float] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(|Δr|, |Δr| / max(r_new, r_old)) — the paper's pruning/frontier metric.

    `floor` guards the denominator for callers whose gathered lanes may hold
    zeros (the compact engine's dead slots); dense ranks are strictly
    positive so the default skips the extra op.
    """
    dr = jnp.abs(r_new - r_old)
    den = jnp.maximum(r_new, r_old)
    if floor is not None:
        den = jnp.maximum(den, floor)
    return dr, dr / den


def rank_step(s: jnp.ndarray, r: jnp.ndarray, affected: jnp.ndarray,
              out_deg: jnp.ndarray, *, alpha: float, n_norm: int,
              tau_f: float, tau_p: float, prune: bool, closed_form: bool,
              track_frontier: bool
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One dense-shaped synchronous rank sweep given the pulled sums `s`.

    Returns (r_new, affected', delta_N, linf_delta). Works unchanged on a
    full [n] vector or on one shard's [n_loc] slice (pass the shard's
    affected mask already AND-ed with its validity mask, and the global
    vertex count as `n_norm`); `linf_delta` is then the *local* norm and the
    caller owns the cross-device `pmax`.
    """
    dt = r.dtype
    d = out_deg.astype(dt)
    rv = rank_value(s, r, d, alpha=alpha,
                    c0=teleport(alpha, n_norm, dt), closed_form=closed_form)
    r_new = jnp.where(affected, rv, r)
    dr, rel = relative_change(r_new, r)
    if prune:
        affected = affected & ~(rel <= tau_p)
    if track_frontier:
        delta_n = rel > tau_f
    else:
        delta_n = jnp.zeros(r.shape, dtype=jnp.bool_)
    return r_new, affected, delta_n, jnp.max(dr)
