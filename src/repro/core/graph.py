"""Host-side graph representation and dynamic-batch machinery.

The paper (Sahu 2024) stores the *transpose* of the current graph G^t' in CSR on
the GPU for pull-based rank computation, and the forward graph G^t for marking
affected vertices. We keep both, plus a TPU-friendly hybrid layout:

  * low in-degree vertices (deg <= d_p)  -> ELLPACK padded index matrix
    (the "thread-per-vertex" side: one VPU lane per vertex), and
  * high in-degree vertices              -> tile-padded CSR slices
    (the "block-per-vertex" side: sequential VMEM tiles per vertex).

All construction is host-side numpy (the paper likewise builds CSR on the CPU
before copying to the device); device arrays are produced by `to_device_arrays`.
Dead ends are eliminated by adding a self-loop to every vertex (paper §5.1.4),
which the DF-P closed form (Eq. 2) then absorbs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "Graph",
    "EllBucket",
    "HybridLayout",
    "HybridRows",
    "BatchUpdate",
    "build_graph",
    "add_self_loops",
    "apply_batch",
    "random_graph",
    "powerlaw_graph",
    "random_batch",
    "temporal_stream",
    "edge_keys",
    "keys_to_edges",
    "next_pow2",
    "ragged_positions",
    "bucket_band_counts",
    "choose_bucket_widths",
    "build_hybrid_rows",
    "build_hybrid",
    "hybrid_caps",
    "layout_slot_stats",
    "graph_from_sorted_keys",
]


# ---------------------------------------------------------------------------
# Edge-key and ragged-index primitives (shared with repro.stream)
# ---------------------------------------------------------------------------

def edge_keys(n: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Pack (src, dst) pairs into sortable int64 keys (src-major order)."""
    return np.asarray(src, np.int64) * n + np.asarray(dst, np.int64)


def keys_to_edges(n: int, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of `edge_keys`."""
    return (keys // n).astype(np.int32), (keys % n).astype(np.int32)


def next_pow2(x, floor: int = 16) -> int:
    """Smallest power of two >= max(x, 1), floored for bucket stability.

    The shared shape-bucketing policy: jitted engines see capacities only
    from this ladder, so the compact engine, the stream delta padding, and
    the snapshot scatter paths all compile O(log) variants total.
    """
    return max(floor, 1 << int(np.ceil(np.log2(max(1, x)))))


def ragged_positions(counts: np.ndarray) -> np.ndarray:
    """Within-segment positions for ragged data: counts [k] -> [sum(counts)]
    array 0..c0-1, 0..c1-1, ... — one vectorized pass, no Python loop."""
    counts = np.asarray(counts, np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    starts = np.cumsum(counts) - counts
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable CSR graph (forward) + its transpose, self-loops guaranteed.

    offsets/targets   : CSR of G   (out-edges)  -- used for frontier marking.
    t_offsets/t_sources: CSR of G' (in-edges)   -- used for rank pull.
    """

    n: int
    offsets: np.ndarray      # [n+1] int64
    targets: np.ndarray      # [m]   int32
    t_offsets: np.ndarray    # [n+1] int64
    t_sources: np.ndarray    # [m]   int32

    @property
    def m(self) -> int:
        return int(self.targets.shape[0])

    def out_degree(self) -> np.ndarray:
        return np.diff(self.offsets).astype(np.int32)

    def in_degree(self) -> np.ndarray:
        return np.diff(self.t_offsets).astype(np.int32)

    def edges(self) -> Tuple[np.ndarray, np.ndarray]:
        src = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.offsets))
        return src, self.targets.copy()

    def has_edge(self, u: int, v: int) -> bool:
        lo, hi = self.offsets[u], self.offsets[u + 1]
        return bool(np.any(self.targets[lo:hi] == v))

    def transpose(self) -> "Graph":
        """G' with edge directions reversed (shares the underlying arrays).

        `build_hybrid(g)` lays out *in*-neighbors; `build_hybrid(g.transpose())`
        therefore lays out out-neighbors — the forward orientation used for
        compacted frontier expansion.
        """
        return Graph(n=self.n, offsets=self.t_offsets, targets=self.t_sources,
                     t_offsets=self.offsets, t_sources=self.targets)


@dataclasses.dataclass(frozen=True)
class BatchUpdate:
    """A batch Δ^t: edge deletions (u,v) and insertions (u,v), dedup'd."""

    del_src: np.ndarray  # int32 [nd]
    del_dst: np.ndarray  # int32 [nd]
    ins_src: np.ndarray  # int32 [ni]
    ins_dst: np.ndarray  # int32 [ni]

    @property
    def size(self) -> int:
        return int(self.del_src.shape[0] + self.ins_src.shape[0])


def _csr_from_edges(n: int, src: np.ndarray, dst: np.ndarray):
    """Build CSR from an edge list (duplicates removed); returns offsets, targets."""
    if src.size:
        key = src.astype(np.int64) * n + dst.astype(np.int64)
        key = np.unique(key)
        src = (key // n).astype(np.int32)
        dst = (key % n).astype(np.int32)
    counts = np.bincount(src, minlength=n).astype(np.int64)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return offsets, dst.astype(np.int32), src, dst


def build_graph(n: int, src: np.ndarray, dst: np.ndarray,
                self_loops: bool = True) -> Graph:
    """Construct a Graph from edge arrays; optionally augment with self-loops."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if self_loops:
        loops = np.arange(n, dtype=np.int32)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
    offsets, targets, usrc, udst = _csr_from_edges(n, src, dst)
    # transpose CSR
    t_offsets, t_sources, _, _ = _csr_from_edges(n, udst, usrc)
    return Graph(n=n, offsets=offsets, targets=targets,
                 t_offsets=t_offsets, t_sources=t_sources)


def graph_from_sorted_keys(n: int, keys: np.ndarray) -> Graph:
    """Build a Graph from already-unique, already-sorted edge keys.

    This is the fast-rebuild path used by `repro.stream.snapshot`: the
    maintained key set is sorted src-major, so the forward CSR falls out of a
    single bincount (no np.unique re-sort as in `build_graph`).
    """
    src, dst = keys_to_edges(n, keys)
    counts = np.bincount(src, minlength=n).astype(np.int64)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    order = np.argsort(dst, kind="stable")
    t_counts = np.bincount(dst, minlength=n).astype(np.int64)
    t_offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(t_counts, out=t_offsets[1:])
    return Graph(n=n, offsets=offsets, targets=dst,
                 t_offsets=t_offsets, t_sources=src[order])


def add_self_loops(n: int, src: np.ndarray, dst: np.ndarray):
    loops = np.arange(n, dtype=np.int32)
    return (np.concatenate([src.astype(np.int32), loops]),
            np.concatenate([dst.astype(np.int32), loops]))


def apply_batch(g: Graph, batch: BatchUpdate) -> Graph:
    """Apply Δ^t to g, returning G^t (self-loops preserved — never deleted)."""
    src, dst = g.edges()
    if batch.del_src.size:
        key = src.astype(np.int64) * g.n + dst.astype(np.int64)
        dkey = batch.del_src.astype(np.int64) * g.n + batch.del_dst.astype(np.int64)
        # never delete self-loops (paper re-adds them with every batch)
        dkey = dkey[batch.del_src != batch.del_dst]
        keep = ~np.isin(key, dkey)
        src, dst = src[keep], dst[keep]
    if batch.ins_src.size:
        src = np.concatenate([src, batch.ins_src.astype(np.int32)])
        dst = np.concatenate([dst, batch.ins_dst.astype(np.int32)])
    return build_graph(g.n, src, dst, self_loops=True)


# ---------------------------------------------------------------------------
# Hybrid degree-bucketed ELL + tiled-CSR device layout (the paper's
# degree-partitioned kernels, generalized to a multi-bucket low side)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EllBucket:
    """One dense ELL block of the low side: rows whose degree fits `width`.

    rows [cap] int32 : row id per slot (sentinel = n_rows for unused slots)
    idx  [cap, width] int32 : neighbor ids, padded with 0
    mask [cap, width] f32   : 1.0 for real edges, 0.0 for padding
    """

    width: int
    rows: np.ndarray
    idx: np.ndarray
    mask: np.ndarray

    @property
    def cap(self) -> int:
        return int(self.rows.shape[0])


def choose_bucket_widths(deg: np.ndarray, d_p: int,
                         max_buckets: int = 4) -> Tuple[int, ...]:
    """Pick ELL bucket widths from the degree histogram (Gunrock-style
    multi-bucket load balancing, arXiv:1701.01170).

    Candidates are the powers of two below `d_p` plus `d_p` itself; a small
    exact DP picks the subset (always containing `d_p`, at most
    `max_buckets`) that minimizes total ELL slots when every row of degree
    <= d_p is stored at the smallest chosen width that fits it. Ties prefer
    fewer buckets. `d_p <= 0` means no ELL side at all -> ().
    """
    if d_p <= 0:
        return ()
    ladder = []
    w = 1
    while w < d_p:
        ladder.append(w)
        w <<= 1
    ladder.append(d_p)
    deg = np.asarray(deg, np.int64)
    low_deg = deg[deg <= d_p]
    if low_deg.size == 0:
        return (d_p,)
    grp = np.searchsorted(ladder, np.maximum(low_deg, 1), side="left")
    counts = np.bincount(grp, minlength=len(ladder)).astype(np.int64)
    pre = np.concatenate([[0], np.cumsum(counts)])
    k = len(ladder)
    inf = float("inf")
    best = [[inf] * (max_buckets + 1) for _ in range(k)]
    back = [[None] * (max_buckets + 1) for _ in range(k)]
    for i in range(k):
        best[i][1] = ladder[i] * int(pre[i + 1])
        for j in range(2, max_buckets + 1):
            for p in range(i):
                cost = best[p][j - 1] + ladder[i] * int(pre[i + 1] - pre[p + 1])
                if cost < best[i][j]:
                    best[i][j] = cost
                    back[i][j] = p
    bj, bcost = 1, best[k - 1][1]
    for j in range(2, max_buckets + 1):
        if best[k - 1][j] < bcost:
            bcost = best[k - 1][j]
            bj = j
    sel = [k - 1]
    i, j = k - 1, bj
    while j > 1:
        i = back[i][j]
        sel.append(i)
        j -= 1
    return tuple(ladder[i] for i in sorted(sel))


def bucket_band_counts(deg: np.ndarray, widths: Tuple[int, ...],
                       d_p: int) -> Tuple[int, ...]:
    """Rows each bucket can hold under the streaming hysteresis.

    Bucket b's occupancy band is (widths[b-1]//2, widths[b]] — a row
    demotes out of b only once its degree drops to half the *narrower*
    width, so every degree in that band may legally sit in b (bucket 0's
    band is [0, widths[0]]). Bands of adjacent buckets overlap, so these
    are per-bucket upper bounds, not a partition: streaming capacity
    planning must use them instead of the initial placement counts, or
    migration drift exhausts a bucket that the placement census said was
    big enough.
    """
    deg = np.asarray(deg, np.int64)
    low = deg[deg <= d_p]
    out = []
    for bi, w in enumerate(widths):
        if bi == 0:
            out.append(int((low <= w).sum()))
        else:
            floor = widths[bi - 1] // 2
            out.append(int(((low > floor) & (low <= w)).sum()))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class HybridLayout:
    """Device-friendly pull layout for the transpose graph G'.

    Low side (in-degree <= d_p): degree buckets — `buckets[b]` is a dense
    `[cap_b, widths[b]]` ELL block holding every row whose degree fits
    `widths[b]` but not `widths[b-1]`, with its own row-id map (see
    `EllBucket`). `bucket_of[v]` gives the bucket index (== len(widths)
    for CSR-side rows) and `slot_of[v]` the row's slot within its side.
    CSR side (high in-degree), tile-padded to `tile` edges:
      hi_ids    [n_hi_cap]      int32 : vertex id per high vertex (pad = n)
      hi_tiles  [t_cap, tile]   int32 : in-neighbor ids, tiles padded with 0
      hi_tmask  [t_cap, tile]   f32   : edge validity
      hi_rowmap [t_cap]         int32 : which *high-slot* each tile belongs to
    Common:
      is_low   [n] bool ; out_deg [n] int32 (of G, for contributions)
      perm     [n] int32 : partition order, low-degree vertices first (Alg. 4)
      n_low    int
    """

    d_p: int
    tile: int
    widths: Tuple[int, ...]
    buckets: Tuple[EllBucket, ...]
    bucket_of: np.ndarray
    slot_of: np.ndarray
    hi_ids: np.ndarray
    hi_tiles: np.ndarray
    hi_tmask: np.ndarray
    hi_rowmap: np.ndarray
    is_low: np.ndarray
    out_deg: np.ndarray
    perm: np.ndarray
    n_low: int

    @property
    def n(self) -> int:
        return int(self.is_low.shape[0])

    @property
    def n_hi_cap(self) -> int:
        return int(self.hi_ids.shape[0])


@dataclasses.dataclass(frozen=True)
class HybridRows:
    """Hybrid bucketed-ELL + tiled-CSR layout of `n_rows` ragged rows — one
    orientation, no graph semantics attached.

    This is the layout *primitive* both scales share: `build_hybrid` wraps it
    for the single-device full graph (row = vertex, ids = global), and
    `core.distributed.build_sharded` stacks one per shard (row = local
    vertex, stored ids = global column ids). Field conventions match
    `HybridLayout`: bucket `rows` and `hi_ids` hold row ids with sentinel
    `n_rows` for unused slots, `hi_rowmap` points pad tiles at slot
    `n_hi_cap - 1` (mask 0).
    """

    d_p: int
    tile: int
    widths: Tuple[int, ...]
    buckets: Tuple[EllBucket, ...]
    bucket_of: np.ndarray   # [n_rows] int32 (len(widths) = CSR side / none)
    slot_of: np.ndarray     # [n_rows] int32 (slot within bucket or hi side)
    hi_ids: np.ndarray      # [n_hi_cap]    int32 (sentinel = n_rows)
    hi_tiles: np.ndarray    # [t_cap, tile] int32
    hi_tmask: np.ndarray    # [t_cap, tile] f32
    hi_rowmap: np.ndarray   # [t_cap]       int32
    is_low: np.ndarray      # [n_rows]      bool
    row_deg: np.ndarray     # [n_rows]      int64

    @property
    def n(self) -> int:
        return int(self.is_low.shape[0])

    @property
    def n_hi_cap(self) -> int:
        return int(self.hi_ids.shape[0])


def build_hybrid_rows(offsets: np.ndarray, data: np.ndarray,
                      d_p: int = 64, tile: int = 1024,
                      n_rows: Optional[int] = None,
                      n_hi_cap: Optional[int] = None,
                      t_cap: Optional[int] = None,
                      widths: Optional[Tuple[int, ...]] = None,
                      bucket_caps: Optional[Tuple[int, ...]] = None
                      ) -> HybridRows:
    """Vectorized hybrid layout of ragged rows (the shared Alg. 4 split).

    `offsets` [k+1] / `data` [offsets[-1]] describe k ragged rows; `n_rows`
    (>= k, default k) pads trailing empty rows so callers can present a
    fixed row capacity (sharded blocks pad |V| to a multiple of the shard
    count). Rows with more than `d_p` entries go to the tiled-CSR side;
    rows with <= d_p entries go to the ELL bucket of the smallest width
    that fits them. `widths` defaults to `choose_bucket_widths` over the
    degree histogram; `bucket_caps` / `n_hi_cap` / `t_cap` fix capacities
    so repeated builds keep identical device shapes (default: exact current
    sizes). Vectorized ragged-fill passes — no per-row Python loop.
    """
    offsets = np.asarray(offsets, np.int64)
    data = np.asarray(data, np.int32)
    k = int(offsets.shape[0]) - 1
    if n_rows is None:
        n_rows = k
    assert n_rows >= k, "n_rows smaller than the described row count"
    deg = np.zeros(n_rows, np.int64)
    deg[:k] = np.diff(offsets)
    is_low = deg <= d_p

    if widths is None:
        widths = choose_bucket_widths(deg[:k], d_p)
    widths = tuple(int(w) for w in widths)
    assert list(widths) == sorted(set(widths)), "widths must be ascending"
    if widths:
        assert widths[-1] == d_p, "top bucket width must equal d_p"
    else:
        assert d_p <= 0, "d_p > 0 requires at least one ELL bucket"
    n_buckets = len(widths)

    # --- ELL buckets (one vectorized ragged-fill pass per bucket) ----------
    bucket_of = np.full(n_rows, n_buckets, dtype=np.int32)
    slot_of = np.zeros(n_rows, dtype=np.int32)
    if n_buckets:
        low_rows = np.nonzero(is_low)[0]
        bucket_of[low_rows] = np.searchsorted(
            widths, np.maximum(deg[low_rows], 1), side="left")
    buckets = []
    for bi, w in enumerate(widths):
        rows_b = np.nonzero(bucket_of == bi)[0]
        cnt = int(rows_b.size)
        cap = max(cnt, 1) if bucket_caps is None else int(bucket_caps[bi])
        assert cnt <= cap, f"bucket_caps[{bi}] too small for this snapshot"
        rows_arr = np.full(cap, n_rows, dtype=np.int32)
        rows_arr[:cnt] = rows_b
        idx = np.zeros((cap, w), dtype=np.int32)
        mask = np.zeros((cap, w), dtype=np.float32)
        slot_of[rows_b] = np.arange(cnt, dtype=np.int32)
        real = rows_b[rows_b < k]     # rows >= k are empty, nothing to fill
        if real.size:
            deg_r = deg[real]
            rr = np.repeat(slot_of[real], deg_r)
            pos = ragged_positions(deg_r)
            src_at = np.repeat(offsets[real], deg_r) + pos
            idx[rr, pos] = data[src_at]
            mask[rr, pos] = 1.0
        buckets.append(EllBucket(width=w, rows=rows_arr, idx=idx, mask=mask))

    # --- tiled CSR side (single scatter; no per-row Python loop) -----------
    hi = np.nonzero(~is_low)[0].astype(np.int32)
    n_hi = int(hi.size)
    if n_hi_cap is None:
        n_hi_cap = max(n_hi, 1)
    assert n_hi <= n_hi_cap, "n_hi_cap too small for this snapshot"
    deg_hi = deg[hi]
    nt_per = (deg_hi + tile - 1) // tile            # tiles per high row
    nt_total = int(nt_per.sum())
    if t_cap is None:
        t_cap = max(nt_total, 1)
    assert nt_total <= t_cap, "t_cap too small for this snapshot"
    hi_tiles = np.zeros((t_cap, tile), dtype=np.int32)
    hi_tmask = np.zeros((t_cap, tile), dtype=np.float32)
    hi_rowmap = np.full(t_cap, n_hi_cap - 1, dtype=np.int32)  # pad tiles -> last slot, mask=0
    if nt_total:
        # flat position of every high entry inside the [t_cap*tile] pool:
        # per-row base (cumsum of nt*tile) + within-row position
        base = np.cumsum(nt_per * tile) - nt_per * tile
        pos = ragged_positions(deg_hi)
        flat_at = np.repeat(base, deg_hi) + pos
        src_at = np.repeat(offsets[hi], deg_hi) + pos
        hi_tiles.reshape(-1)[flat_at] = data[src_at]
        hi_tmask.reshape(-1)[flat_at] = 1.0
        hi_rowmap[:nt_total] = np.repeat(
            np.arange(n_hi, dtype=np.int32), nt_per)
    hi_ids = np.full(n_hi_cap, n_rows, dtype=np.int32)  # sentinel = "no row"
    hi_ids[:n_hi] = hi
    slot_of[hi] = np.arange(n_hi, dtype=np.int32)

    hr = HybridRows(d_p=d_p, tile=tile, widths=widths, buckets=tuple(buckets),
                    bucket_of=bucket_of, slot_of=slot_of,
                    hi_ids=hi_ids, hi_tiles=hi_tiles, hi_tmask=hi_tmask,
                    hi_rowmap=hi_rowmap, is_low=is_low, row_deg=deg)
    _count_layout(hr)
    return hr


def build_hybrid(g: Graph, d_p: int = 64, tile: int = 1024,
                 n_hi_cap: Optional[int] = None,
                 t_cap: Optional[int] = None,
                 widths: Optional[Tuple[int, ...]] = None,
                 bucket_caps: Optional[Tuple[int, ...]] = None
                 ) -> HybridLayout:
    """Partition vertices by in-degree (Alg. 4) and build the hybrid layout.

    A thin graph-aware wrapper over `build_hybrid_rows` (rows = in-neighbor
    lists of the transpose CSR). `widths` defaults to the degree-histogram
    bucket choice; `bucket_caps` / `n_hi_cap` / `t_cap` allow fixed
    capacities across dynamic snapshots so the jitted update never
    recompiles; they default to the exact current sizes.
    """
    from .partition import partition_by_degree

    indeg = g.in_degree()
    perm, n_low = partition_by_degree(indeg, d_p)
    hr = build_hybrid_rows(g.t_offsets, g.t_sources, d_p=d_p, tile=tile,
                           n_hi_cap=n_hi_cap, t_cap=t_cap,
                           widths=widths, bucket_caps=bucket_caps)
    return HybridLayout(
        d_p=d_p, tile=tile, widths=hr.widths, buckets=hr.buckets,
        bucket_of=hr.bucket_of, slot_of=hr.slot_of,
        hi_ids=hr.hi_ids, hi_tiles=hr.hi_tiles, hi_tmask=hr.hi_tmask,
        hi_rowmap=hr.hi_rowmap, is_low=hr.is_low, out_deg=g.out_degree(),
        perm=perm, n_low=int(n_low))


def hybrid_caps(lay) -> dict:
    """Capacity signature of a layout — pass as **caps to `build_hybrid` to
    rebuild a later snapshot with identical device shapes (no recompiles)."""
    return dict(d_p=lay.d_p, tile=lay.tile, n_hi_cap=lay.n_hi_cap,
                t_cap=int(lay.hi_tiles.shape[0]), widths=lay.widths,
                bucket_caps=tuple(b.cap for b in lay.buckets))


def layout_slot_stats(lay) -> dict:
    """Edge-slot efficiency of a layout: how many slots one full pull
    gathers vs how many real edges it carries (padded-edge accounting).

    Works on HybridRows / HybridLayout. `ell_slots` counts every bucket's
    `cap * width`; `hi_slots` counts `t_cap * tile`; `real_edges` counts
    mask bits actually set. `gathered_slots / real_edges` is the padding
    overhead one iteration pays.
    """
    ell_slots = sum(b.cap * b.width for b in lay.buckets)
    hi_slots = int(lay.hi_tiles.shape[0] * lay.hi_tiles.shape[1])
    real = int(sum(int(b.mask.sum()) for b in lay.buckets)
               + int(lay.hi_tmask.sum()))
    return dict(real_edges=real, ell_slots=ell_slots, hi_slots=hi_slots,
                gathered_slots=ell_slots + hi_slots)


def _count_layout(hr: HybridRows) -> None:
    """Record padded-edge-efficiency counters for each layout build."""
    from ..obs import get_registry

    st = layout_slot_stats(hr)
    reg = get_registry()
    reg.inc("layout.builds")
    reg.inc("layout.real_edges", st["real_edges"])
    reg.inc("layout.ell_slots", st["ell_slots"])
    reg.inc("layout.hi_slots", st["hi_slots"])
    reg.inc("layout.gathered_slots", st["gathered_slots"])


# ---------------------------------------------------------------------------
# Synthetic graph + batch generators (paper §5.1.3/5.1.4 protocol, scaled down)
# ---------------------------------------------------------------------------

def random_graph(n: int, m: int, seed: int = 0) -> Graph:
    """Uniform random directed graph with self-loops."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m, dtype=np.int64).astype(np.int32)
    dst = rng.integers(0, n, size=m, dtype=np.int64).astype(np.int32)
    return build_graph(n, src, dst, self_loops=True)


def powerlaw_graph(n: int, m: int, alpha: float = 2.1, seed: int = 0) -> Graph:
    """Power-law in-degree graph (Zipf targets) — exercises the high/low split."""
    rng = np.random.default_rng(seed)
    # Zipf-ranked popularity for *targets* => skewed in-degree
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    dst = rng.choice(n, size=m, p=p).astype(np.int32)
    src = rng.integers(0, n, size=m, dtype=np.int64).astype(np.int32)
    return build_graph(n, src, dst, self_loops=True)


def random_batch(g: Graph, frac: float, insert_frac: float = 0.8,
                 seed: int = 0) -> BatchUpdate:
    """Paper §5.1.4: batch of size frac*|E|, 80% insertions / 20% deletions.

    Insertions pick uniform vertex pairs; deletions sample existing edges
    uniformly. No vertices are added/removed. Self-loops survive deletion.
    """
    rng = np.random.default_rng(seed)
    b = max(1, int(round(frac * g.m)))
    ni = int(round(b * insert_frac))
    nd = b - ni
    ins_src = rng.integers(0, g.n, size=ni).astype(np.int32)
    ins_dst = rng.integers(0, g.n, size=ni).astype(np.int32)
    src, dst = g.edges()
    if nd > 0 and g.m > 0:
        pick = rng.integers(0, g.m, size=nd)
        del_src, del_dst = src[pick], dst[pick]
        nonloop = del_src != del_dst
        del_src, del_dst = del_src[nonloop], del_dst[nonloop]
    else:
        del_src = del_dst = np.zeros(0, np.int32)
    return BatchUpdate(del_src=del_src, del_dst=del_dst,
                       ins_src=ins_src, ins_dst=ins_dst)


def temporal_stream(n: int, n_edges: int, n_batches: int, warm_frac: float = 0.9,
                    seed: int = 0):
    """Emulate the real-world-dynamic protocol: preferential-attachment-ish
    temporal edge stream; load `warm_frac` as the base graph, then yield
    `n_batches` insertion-only batches of the remainder (paper §5.1.4).

    Returns (base_graph, [BatchUpdate...]).
    """
    rng = np.random.default_rng(seed)
    # growing-popularity stream: later edges prefer earlier vertices (Zipf)
    ranks = np.arange(1, n + 1, dtype=np.float64) ** -1.5
    p = ranks / ranks.sum()
    src = rng.choice(n, size=n_edges, p=p).astype(np.int32)
    dst = rng.choice(n, size=n_edges, p=p).astype(np.int32)
    warm = int(n_edges * warm_frac)
    base = build_graph(n, src[:warm], dst[:warm], self_loops=True)
    rest = n_edges - warm
    per = max(1, rest // n_batches)
    batches = []
    for k in range(n_batches):
        lo = warm + k * per
        hi = min(warm + (k + 1) * per, n_edges)
        if lo >= hi:
            break
        batches.append(BatchUpdate(
            del_src=np.zeros(0, np.int32), del_dst=np.zeros(0, np.int32),
            ins_src=src[lo:hi], ins_dst=dst[lo:hi]))
    return base, batches
