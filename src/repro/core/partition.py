"""Alg. 4 — parallel vertex partitioning by degree.

The paper partitions vertex IDs into low-degree-first order with two
exclusive-prefix-sum passes. We provide both a host numpy version (used when
(re)building layouts per snapshot) and a jit-able jnp version that preserves the
paper's exclusive-scan formulation exactly — it is used by tests to show the
partition itself is a data-parallel TPU-friendly op, and by the distributed
runtime when repartitioning on elastic resize.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["partition_by_degree", "partition_by_degree_jax"]


def partition_by_degree(deg: np.ndarray, d_p: int):
    """Return (perm, n_low): vertex ids with deg<=d_p first, stable order.

    Mirrors Alg. 4: boolean buffer -> exclusive scan -> scatter, twice.
    """
    deg = np.asarray(deg)
    n = deg.shape[0]
    low = deg <= d_p
    bk = np.zeros(n + 1, dtype=np.int64)
    bk[1:] = np.cumsum(low)           # exclusive scan of low flags
    n_low = int(bk[n])
    perm = np.empty(n, dtype=np.int32)
    ids = np.arange(n, dtype=np.int32)
    perm[bk[:n][low]] = ids[low]
    bk2 = np.zeros(n + 1, dtype=np.int64)
    bk2[1:] = np.cumsum(~low)
    perm[n_low + bk2[:n][~low]] = ids[~low]
    return perm, n_low


@jax.jit
def partition_by_degree_jax(deg: jnp.ndarray, d_p: int | jnp.ndarray):
    """Device-side Alg. 4 (two exclusive scans + scatter). Returns (perm, n_low)."""
    n = deg.shape[0]
    low = deg <= d_p
    ids = jnp.arange(n, dtype=jnp.int32)
    scan_low = jnp.cumsum(low) - low          # exclusive scan
    n_low = jnp.sum(low)
    scan_hi = jnp.cumsum(~low) - (~low)
    pos = jnp.where(low, scan_low, n_low + scan_hi)
    perm = jnp.zeros(n, dtype=jnp.int32).at[pos].set(ids)
    return perm, n_low
