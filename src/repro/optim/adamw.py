"""AdamW with f32 state, decoupled weight decay, global-norm clip.

States are sharded exactly like their parameters (model.py propagates the
param PartitionSpecs to the state tree), i.e. ZeRO-style sharding falls out
of the model-parallel specs for every sharded tensor.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, state: AdamWState, params, *, lr=3e-4, b1=0.9,
                 b2=0.95, eps=1e-8, wd=0.1, clip=1.0):
    grads, gnorm = clip_by_global_norm(grads, clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        u = u + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
