"""Gradient compression for the data-parallel all-reduce: int8 with
per-tensor scale + error feedback. Cuts the DP collective term 4x (bf16->int8
with an f32 scale per tensor); the residual accumulator keeps the compression
unbiased over steps (standard EF-SGD argument). Enabled per-config when the
roofline shows the collective term dominating (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_grads", "decompress_grads", "ef_init", "ef_apply"]


def compress_grads(grads):
    """-> (int8 tree, scale tree). Call BEFORE psum; psum the int32-upcast."""
    def one(g):
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        return q, scale
    qs = jax.tree.map(one, grads)
    leaf = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda o: o[0], qs, is_leaf=leaf),
            jax.tree.map(lambda o: o[1], qs, is_leaf=leaf))


def decompress_grads(q, scales):
    return jax.tree.map(lambda qi, s: qi.astype(jnp.float32) * s, q, scales)


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_apply(grads, residual):
    """Add residual, compress, keep the new residual. Returns
    (q, scales, new_residual)."""
    g_corr = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads,
                          residual)
    q, scales = compress_grads(g_corr)
    recon = decompress_grads(q, scales)
    new_res = jax.tree.map(lambda g, r: g - r, g_corr, recon)
    return q, scales, new_res
