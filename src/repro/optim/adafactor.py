"""Adafactor (factored second moment) — for the MoE giants where AdamW's
8 bytes/param of state cannot fit a single v5e pod (DESIGN.md, deepseek-v3).

Factored along the two trailing dims for rank >= 2 tensors; full second
moment for vectors. No first moment (beta1 = 0), update clipping d=1.0,
relative step size replaced by fixed lr for simplicity (documented)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdafactorState", "adafactor_init", "adafactor_update"]


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: dict   # row factors (or full v for rank-1)
    vc: dict   # col factors (zeros placeholder for rank-1)


def _factored(p):
    return p.ndim >= 2


def adafactor_init(params):
    def vr_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr_init, params),
                          vc=jax.tree.map(vc_init, params))


def adafactor_update(grads, state: AdafactorState, params, *, lr=1e-3,
                     decay=0.8, eps=1e-30, clip=1.0, wd=0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-decay)

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p):
            vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = (vr2[..., None] * vc2[..., None, :]
                     / jnp.maximum(jnp.mean(vr2, axis=-1,
                                            keepdims=True)[..., None], eps))
            u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
        else:
            vr2 = beta2 * vr + (1 - beta2) * g2
            vc2 = vc
            u = g * jax.lax.rsqrt(jnp.maximum(vr2, eps))
        # update clipping (RMS <= clip)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip)
        if wd:
            u = u + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr2, vc2

    out = jax.tree.map(upd, grads, state.vr, state.vc, params)
    tup = lambda i: jax.tree.map(lambda o: o[i], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return tup(0), AdafactorState(step=step, vr=tup(1), vc=tup(2)), \
        jnp.zeros(())
