from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .adafactor import AdafactorState, adafactor_init, adafactor_update
from .compress import compress_grads, decompress_grads, ef_init, ef_apply

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "AdafactorState", "adafactor_init", "adafactor_update",
           "compress_grads", "decompress_grads", "ef_init", "ef_apply"]
