"""Data pipeline: deterministic, seekable token streams.

Training at scale needs (a) a data source whose position is a pure function
of the step (so restart-from-checkpoint replays nothing and skips nothing),
(b) per-host sharding of the batch dimension, (c) zero-copy staging to
device. `SyntheticLM` generates a fixed-vocabulary Markov-ish stream on the
fly (CPU-cheap, infinite); `PackedFile` memory-maps a token file and serves
packed sequences. Both expose `batch_at(step)` — the seekable contract used
by the restart machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "PackedFile", "batch_for"]


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    embed_dim: Optional[int] = None     # audio/vlm stub: emit embeddings too
    mrope: bool = False

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a given step (seekable)."""
        rng = np.random.default_rng((self.seed, step))
        # cheap structured stream: mixture of ramps and repeats, not uniform
        base = rng.integers(0, self.vocab, (self.batch, self.seq // 2),
                            dtype=np.int32)
        tokens = np.concatenate([base, (base + 1) % self.vocab], axis=1)
        out = {}
        if self.embed_dim is None:
            out["tokens"] = tokens
        else:
            emb = rng.standard_normal((self.batch, self.seq,
                                       self.embed_dim)).astype(np.float32)
            out["embeddings"] = emb
            out["labels"] = tokens
        if self.mrope:
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                  (self.batch, 3, self.seq)).copy()
            out["positions"] = pos
        return out


@dataclasses.dataclass
class PackedFile:
    """Memory-mapped int32 token file served as packed sequences."""
    path: str
    batch: int
    seq: int

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._per_step = self.batch * self.seq

    @property
    def n_steps(self) -> int:
        return self._data.shape[0] // self._per_step

    def batch_at(self, step: int) -> dict:
        lo = (step % self.n_steps) * self._per_step
        chunk = np.asarray(self._data[lo:lo + self._per_step])
        return {"tokens": chunk.reshape(self.batch, self.seq)}


def batch_for(cfg, B: int, S: int, step: int, seed: int = 0) -> dict:
    """Arch-aware synthetic batch (matches input_specs structurally)."""
    src = SyntheticLM(vocab=cfg.vocab, batch=B, seq=S, seed=seed,
                      embed_dim=cfg.d_model if cfg.embed_inputs else None,
                      mrope=cfg.rope == "mrope")
    return src.batch_at(step)
