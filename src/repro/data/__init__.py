from .pipeline import SyntheticLM, PackedFile, batch_for
__all__ = ["SyntheticLM", "PackedFile", "batch_for"]
