"""repro.obs — observability layer: iteration traces, spans, sinks, gates.

The paper's headline claims are observability claims (iterations to
convergence, fraction of affected vertices per batch, per-kernel time
splits — Figs. 1-5); this subsystem makes every one of them inspectable:

  * `trace`  — fixed-shape ``TraceBuffer`` carried through every engine's
    ``lax.while_loop`` as aux state (opt-in ``trace=True``; no host
    callbacks in the hot path; ranks identical with tracing off or on);
  * `spans`  — host-side wall-clock spans + monotonic counters with
    optional ``jax.profiler`` trace annotations around kernel dispatch;
  * `report` — ``RunReport`` structured sink (JSON / JSONL) behind
    ``benchmarks.run``'s ``BENCH_obs.json``;
  * `check`  — ``python -m repro.obs.check`` regression gate diffing two
    bench reports (see DESIGN.md §10);
  * `flight` — always-on bounded ring buffer of structured events (what
    happened, in order — the post-failure record counters can't give);
  * `hist`   — log-bucketed latency histograms (p50/p95/p99 per span),
    ``SLOConfig`` breach budgets + on-demand profiler capture;
  * `postmortem` — failure bundles (flight tail + health + trace + registry
    snapshot), rendered by ``python -m repro.obs.postmortem`` (DESIGN §14).
"""
from .trace import (ENGINE_IDS, ENGINE_NAMES, TraceBuffer, maybe_summary,
                    trace_init, trace_record, trace_summary)
from .spans import Registry, Span, get_registry, reset_registry
from .report import RunReport, load_report, validate_report
from .flight import (FlightEvent, FlightRecorder, get_flight, obs_enabled,
                     reset_flight, set_obs_enabled)
from .hist import Histogram, SLOConfig, percentiles_from_samples
from .postmortem import load_bundle, write_bundle

__all__ = [
    "ENGINE_IDS", "ENGINE_NAMES", "TraceBuffer", "maybe_summary",
    "trace_init", "trace_record", "trace_summary",
    "Registry", "Span", "get_registry", "reset_registry",
    "RunReport", "load_report", "validate_report",
    "FlightEvent", "FlightRecorder", "get_flight", "reset_flight",
    "obs_enabled", "set_obs_enabled",
    "Histogram", "SLOConfig", "percentiles_from_samples",
    "write_bundle", "load_bundle",
]
