"""Post-mortem bundles: everything needed to diagnose a dead stream.

When the escalation ladder exhausts (every recovery rung returned an
unhealthy solve) or a restore fails validation, counters alone cannot
reconstruct *what happened* — the operator needs the ordered record. A
bundle is one directory, ``postmortem-<stamp>/``, containing

  * ``bundle.json``  — reason, decoded health word (``describe_health``),
    the failing solve's TraceBuffer summary, the span/counter/histogram
    registry snapshot, the quarantine report, the last journal sequence
    number, SLO/flight summaries, and environment provenance;
  * ``flight.jsonl`` — the flight-recorder tail, one event per line
    (greppable without loading the JSON document).

``python -m repro.obs.postmortem <dir>`` renders a bundle human-readable;
pass the parent directory to render the newest bundle under it. Writing is
best-effort by design: a post-mortem must never raise through the failure
path it is documenting (``write_bundle`` swallows IO errors and returns
None; the caller's counters record the skip).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional

__all__ = ["SCHEMA", "write_bundle", "load_bundle", "render", "main"]

SCHEMA = "repro.obs/postmortem-v1"

#: flight events preserved in the bundle (the tail is what matters; the
#: ring itself may hold more)
TAIL = 256

_seq = 0  # per-process bundle counter (uniquifies same-second bundles)


def _stamp() -> str:
    global _seq
    _seq += 1
    return f"{time.strftime('%Y%m%dT%H%M%S')}-{os.getpid()}-{_seq:03d}"


def _env() -> dict:
    try:
        import jax
        return {"jax": jax.__version__, "backend": jax.default_backend(),
                "device_count": jax.device_count()}
    except Exception:  # pragma: no cover
        return {}


def write_bundle(parent: str, *, reason: str, health: int = 0,
                 trace: Optional[dict] = None, registry=None, flight=None,
                 quarantine: Optional[dict] = None,
                 journal_seq: Optional[int] = None,
                 extra: Optional[dict] = None) -> Optional[str]:
    """Write one bundle directory under ``parent``; returns its path.

    ``registry`` / ``flight`` default to the process-wide instances. Never
    raises: on any failure the bundle is skipped and None returned (the
    stream's failure path must stay clear)."""
    from .flight import get_flight
    from .spans import get_registry
    try:
        from ..guard.health import describe_health, health_flags
        reg = registry if registry is not None else get_registry()
        fl = flight if flight is not None else get_flight()
        events = [e.as_dict() for e in fl.tail(TAIL)]
        doc = {
            "schema": SCHEMA,
            "reason": reason,
            "created_unix": time.time(),
            "env": _env(),
            "health": {"word": int(health),
                       "flags": list(health_flags(health)),
                       "describe": describe_health(health)},
            "journal_seq": journal_seq,
            "quarantine": quarantine,
            "trace": trace,
            "registry": reg.report(),
            "flight": {**fl.summary(), "tail": len(events)},
            "extra": extra or {},
        }
        path = os.path.join(parent, f"postmortem-{_stamp()}")
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "bundle.json"), "w") as f:
            json.dump(doc, f, indent=1, default=str)
            f.write("\n")
        with open(os.path.join(path, "flight.jsonl"), "w") as f:
            for e in events:
                f.write(json.dumps(e, default=str) + "\n")
        get_registry().inc("postmortem.bundles")
        get_flight().emit("postmortem.write", path=path, reason=reason)
        return path
    except Exception:
        try:
            get_registry().inc("postmortem.failed")
        except Exception:  # pragma: no cover
            pass
        return None


def _resolve(path: str) -> str:
    """Accept a bundle dir, or a parent holding ``postmortem-*`` dirs (the
    newest wins), or a direct ``bundle.json`` path."""
    if os.path.isfile(path):
        return os.path.dirname(path) or "."
    if os.path.isfile(os.path.join(path, "bundle.json")):
        return path
    cands = sorted(d for d in os.listdir(path)
                   if d.startswith("postmortem-")
                   and os.path.isfile(os.path.join(path, d, "bundle.json")))
    if not cands:
        raise FileNotFoundError(f"no post-mortem bundle under {path}")
    return os.path.join(path, cands[-1])


def load_bundle(path: str) -> dict:
    with open(os.path.join(_resolve(path), "bundle.json")) as f:
        return json.load(f)


def render(path: str, out=None) -> None:
    """Human-readable rendering of one bundle."""
    out = out or sys.stdout
    bdir = _resolve(path)
    doc = load_bundle(bdir)

    def w(line=""):
        print(line, file=out)

    w(f"post-mortem bundle: {bdir}")
    w(f"  schema   {doc.get('schema')}")
    w(f"  reason   {doc.get('reason')}")
    created = doc.get("created_unix")
    if created:
        w(f"  created  {time.strftime('%Y-%m-%d %H:%M:%S', time.localtime(created))}")
    env = doc.get("env") or {}
    if env:
        w("  env      " + " ".join(f"{k}={v}" for k, v in env.items()))
    h = doc.get("health") or {}
    w(f"\nhealth: {h.get('describe', 'ok')} (word={h.get('word', 0)})")
    if doc.get("journal_seq") is not None:
        w(f"journal: last seq {doc['journal_seq']}")
    q = doc.get("quarantine")
    if q:
        w(f"quarantine: {q}")

    tr = doc.get("trace")
    if tr:
        w(f"\nfailing solve: engine={tr.get('engine')} "
          f"iters={tr.get('iters')} linf_final={tr.get('linf_final')} "
          f"frontier_peak={tr.get('frontier_peak')}")
        linf = [x for x in (tr.get("linf_delta") or []) if x is not None]
        if linf:
            head = ", ".join(f"{x:.3g}" for x in linf[:6])
            tail = f", ..., {linf[-1]:.3g}" if len(linf) > 6 else ""
            w(f"  linf series: [{head}{tail}]")

    reg = doc.get("registry") or {}
    counters = reg.get("counters") or {}
    if counters:
        w("\ncounters:")
        for k, v in counters.items():
            w(f"  {k:<40} {v}")
    spans = reg.get("spans") or {}
    if spans:
        w("\nspans (count / mean / p99 / max, ms):")
        for k, s in spans.items():
            p99 = s.get("p99_s")
            w(f"  {k:<32} {s['count']:>6}  {s['mean_s'] * 1e3:>9.3f}  "
              f"{(p99 * 1e3 if p99 is not None else float('nan')):>9.3f}  "
              f"{s['max_s'] * 1e3:>9.3f}")

    fl = doc.get("flight") or {}
    w(f"\nflight recorder: {fl.get('total', 0)} events "
      f"({fl.get('dropped', 0)} dropped, tail of {fl.get('tail', 0)} kept)")
    jl = os.path.join(bdir, "flight.jsonl")
    if os.path.isfile(jl):
        with open(jl) as f:
            events = [json.loads(line) for line in f if line.strip()]
        for e in events[-40:]:
            data = " ".join(f"{k}={v}" for k, v in (e.get("data") or {}).items())
            w(f"  [{e['seq']:>6}] {e['ts']:>12.6f} {e['kind']:<28} {data}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.postmortem",
        description="Render a post-mortem bundle human-readable.")
    p.add_argument("path", help="bundle dir, its parent, or bundle.json")
    args = p.parse_args(argv)
    try:
        render(args.path)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
