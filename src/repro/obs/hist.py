"""Log-bucketed latency histograms + SLO config + profiler capture hooks.

The paper's headline numbers are *latency distributions over batches*
(Static 31x/5.9x, DF-P 2.1-3.1x are medians of many runs), yet span stats
only keep count/total/min/max — a p99-regressing engine choice or a
one-in-fifty slow rebuild is invisible in a mean. ``Histogram`` fixes that
with HDR-style log-spaced buckets: ``buckets_per_decade`` geometric buckets
per decade over ``[min_value, max_value)`` seconds, so relative error is a
constant ~``10^(1/bpd)`` (~6.6% at the default 36/decade) at any magnitude,
``add`` is one ``math.log10`` + an integer increment (no allocation, no
sorting, safe inside the always-on path), and percentiles come from one
cumulative walk at report time.

``SLOConfig`` names the budget a ``StreamSession`` must hold (solve p99 in
microseconds) and what to do on breach: bump ``slo.breach.*`` counters,
emit a flight event, and — the on-demand profiler hook — arm
``jax.profiler`` trace capture around the next ``capture_batches`` batches,
so the kernel-level timeline of the *slow* regime lands on disk without
paying profiler overhead in the steady state. The existing
``annotate=True`` span plumbing means the ``solve.*`` / ``session.solve``
span names appear on that captured timeline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

__all__ = ["Histogram", "SLOConfig", "percentiles_from_samples",
           "start_profiler", "stop_profiler"]


class Histogram:
    """Log-bucketed histogram of nonnegative samples (seconds by default).

    Not thread-safe by itself — the owning ``Registry`` serializes access
    under its lock; standalone users on one thread need nothing.
    """

    __slots__ = ("min_value", "buckets_per_decade", "_counts", "count",
                 "total", "min", "max")

    def __init__(self, min_value: float = 1e-7, max_value: float = 1e4,
                 buckets_per_decade: int = 36):
        if not (0 < min_value < max_value):
            raise ValueError("need 0 < min_value < max_value")
        self.min_value = float(min_value)
        self.buckets_per_decade = int(buckets_per_decade)
        decades = math.log10(max_value / min_value)
        nb = int(math.ceil(decades * buckets_per_decade)) + 1
        self._counts: List[int] = [0] * nb
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = 0.0

    def _index(self, v: float) -> int:
        if v <= self.min_value:
            return 0
        i = int(math.log10(v / self.min_value) * self.buckets_per_decade)
        return min(i, len(self._counts) - 1)

    def _upper(self, i: int) -> float:
        """Upper bound of bucket ``i`` — the value a percentile reports
        (pessimistic by at most one bucket width)."""
        return self.min_value * 10.0 ** ((i + 1) / self.buckets_per_decade)

    def add(self, v: float) -> None:
        v = float(v)
        if not (v >= 0.0) or v != v:  # negatives / NaN: not a latency
            return
        self._counts[self._index(v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def merge(self, other: "Histogram") -> None:
        if (other.min_value != self.min_value
                or other.buckets_per_decade != self.buckets_per_decade
                or len(other._counts) != len(self._counts)):
            raise ValueError("histogram layouts differ")
        for i, c in enumerate(other._counts):
            self._counts[i] += c
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def percentile(self, p: float) -> Optional[float]:
        """Value at percentile ``p`` in [0, 100]; None when empty. Clamped
        to the exact observed [min, max] so tiny sample counts never report
        a bucket bound outside the data."""
        if self.count == 0:
            return None
        target = max(1, int(math.ceil(self.count * p / 100.0)))
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= target:
                return min(max(self._upper(i), self.min), self.max)
        return self.max  # pragma: no cover - counts always sum to count

    def as_dict(self) -> dict:
        """The percentile snapshot reports embed (seconds)."""
        if self.count == 0:
            return {"count": 0}
        return {"count": self.count,
                "p50_s": self.percentile(50),
                "p95_s": self.percentile(95),
                "p99_s": self.percentile(99),
                "max_s": self.max}


def percentiles_from_samples(samples: Sequence[float]) -> dict:
    """Exact {p50, p95, p99, max} (seconds) from a raw sample list — for
    benches that kept every per-batch latency and don't need bucketing."""
    xs = sorted(float(s) for s in samples)
    if not xs:
        return {}

    def pick(p):
        return xs[min(len(xs) - 1,
                      max(0, int(math.ceil(len(xs) * p / 100.0)) - 1))]

    return {"p50_s": pick(50), "p95_s": pick(95), "p99_s": pick(99),
            "max_s": xs[-1]}


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Latency SLO for a ``StreamSession`` (DESIGN.md §14).

    The session feeds every solve's wall-clock into a per-session
    ``Histogram``; once ``min_samples`` have accumulated, a running p99
    above ``solve_p99_us`` is a breach: ``slo.breach.solve_p99`` increments
    every breaching batch, a ``slo.breach`` flight event is emitted, and —
    when ``capture_batches > 0`` — profiler capture is armed around the
    next N batches (one auto-capture per session; re-arm explicitly with
    ``session.arm_capture``)."""
    #: p99 budget for the per-batch solve wall-clock, microseconds
    solve_p99_us: float = float("inf")
    #: minimum solve samples before the p99 is judged (cold-start guard:
    #: the first batches carry jit compilation)
    min_samples: int = 20
    #: batches to run under ``jax.profiler`` trace after a breach (0 = off)
    capture_batches: int = 0
    #: trace output directory (None: ``<journal_dir>/profile`` or
    #: ``./profile``)
    capture_dir: Optional[str] = None


# -- profiler capture (thin wrappers so tests can monkeypatch) --------------

def start_profiler(log_dir: str) -> bool:
    """Start a ``jax.profiler`` trace into ``log_dir``; False on failure
    (profiler availability varies by backend — a failed capture must never
    take the stream down)."""
    try:
        import jax.profiler
        jax.profiler.start_trace(log_dir)
        return True
    except Exception:
        return False


def stop_profiler() -> bool:
    try:
        import jax.profiler
        jax.profiler.stop_trace()
        return True
    except Exception:
        return False
