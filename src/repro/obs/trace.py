"""Iteration-level telemetry: a fixed-shape trace carried through the loop.

Every engine's convergence loop is a jitted ``lax.while_loop``; host
callbacks from inside it would serialize the hot path. Instead the trace is
an ordinary piece of loop state — a ``TraceBuffer`` of ``[max_iter]``-shaped
arrays written once per iteration with ``.at[i].set`` — so tracing adds a
few reductions and scatters per iteration and *no* host synchronization.
The buffer leaves the loop with the final state and is summarized host-side
(`trace_summary`) after the solve completes.

Invariant (tested): the rank math never reads the trace, so ``trace=True``
produces bit-identical ranks and iteration counts to ``trace=False``.

Per-iteration channels (the paper's Fig. 1-5 quantities):

  linf      L∞ |Δr| of the sweep — the convergence curve
  frontier  |{v : δ_V[v]}| entering the sweep (post-expansion) — the
            "fraction of vertices affected" series
  delta_n   |{v : δ_N[v]}| flagged for the next expansion
  pruned    vertices dropped from δ_V by the τ_p prune this iteration
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["ENGINE_IDS", "ENGINE_NAMES", "TraceBuffer", "trace_init",
           "trace_record", "trace_summary"]

# Stable engine ids (the TraceBuffer stores the id; sinks store the name).
ENGINE_IDS = {
    "static": 0, "nd": 1, "dt": 2, "df": 3, "dfp": 4,
    "df_compact": 5, "dfp_compact": 6,
    "static_1d": 7, "dfp_1d": 8, "static_2d": 9, "dfp_2d": 10,
}
ENGINE_NAMES = {v: k for k, v in ENGINE_IDS.items()}


class TraceBuffer(NamedTuple):
    """Per-iteration telemetry, fixed shape [cap] (cap = params.max_iter)."""
    linf: jnp.ndarray      # [cap] rank dtype; L-inf |dr| per iteration
    frontier: jnp.ndarray  # [cap] int32; |affected| entering the sweep
    delta_n: jnp.ndarray   # [cap] int32; |delta_N| flagged this iteration
    pruned: jnp.ndarray    # [cap] int32; vertices pruned from affected
    engine: jnp.ndarray    # []    int32; ENGINE_IDS value

    @property
    def cap(self) -> int:
        return self.linf.shape[0]


def trace_init(cap: int, dtype, engine: str) -> TraceBuffer:
    """Fresh buffer. Unwritten lanes stay at the -1 / NaN sentinels so a
    summary truncated by a wrong iteration count is visibly wrong rather
    than silently zero."""
    return TraceBuffer(
        linf=jnp.full((cap,), jnp.nan, dtype),
        frontier=jnp.full((cap,), -1, jnp.int32),
        delta_n=jnp.full((cap,), -1, jnp.int32),
        pruned=jnp.full((cap,), -1, jnp.int32),
        engine=jnp.asarray(ENGINE_IDS[engine], jnp.int32))


def trace_record(tb: TraceBuffer, i: jnp.ndarray, *, linf, frontier,
                 delta_n, pruned) -> TraceBuffer:
    """Write iteration i's channels (drop-mode: an out-of-cap write — only
    possible via a caller's offset arithmetic — is a no-op, never OOB)."""
    return TraceBuffer(
        linf=tb.linf.at[i].set(jnp.asarray(linf, tb.linf.dtype),
                               mode="drop"),
        frontier=tb.frontier.at[i].set(
            jnp.asarray(frontier, jnp.int32), mode="drop"),
        delta_n=tb.delta_n.at[i].set(
            jnp.asarray(delta_n, jnp.int32), mode="drop"),
        pruned=tb.pruned.at[i].set(
            jnp.asarray(pruned, jnp.int32), mode="drop"),
        engine=tb.engine)


def _col(x: np.ndarray) -> list:
    """JSON-safe python list (non-finite floats -> None: strict JSON has no
    Infinity/NaN; the inf lanes are the distributed delta_every skip marker
    and the compact engine's overflow marker)."""
    out = []
    for v in x.tolist():
        if isinstance(v, float) and not np.isfinite(v):
            out.append(None)
        else:
            out.append(v)
    return out


def trace_summary(tb: TraceBuffer, iters) -> dict:
    """Host-side summary of a completed solve: series trimmed to the actual
    iteration count, plus the derived scalars the bench sink stores."""
    it = int(iters)
    linf = np.asarray(tb.linf)[:it]
    frontier = np.asarray(tb.frontier)[:it]
    finite = linf[np.isfinite(linf)]
    return {
        "engine": ENGINE_NAMES[int(tb.engine)],
        "iters": it,
        "linf_delta": _col(linf),
        "frontier": _col(frontier),
        "delta_n": _col(np.asarray(tb.delta_n)[:it]),
        "pruned": _col(np.asarray(tb.pruned)[:it]),
        "frontier_peak": int(frontier.max()) if it else 0,
        "frontier_final": int(frontier[-1]) if it else 0,
        "linf_final": float(finite[-1]) if finite.size else None,
    }


def maybe_summary(result, trace: bool) -> tuple:
    """Split an engine return into ((ranks, iters), summary-or-None).

    Engines return (r, iters) untraced and (r, iters, TraceBuffer) traced;
    callers that thread a ``trace`` flag through (StreamSession, benches)
    use this to stay agnostic."""
    if not trace:
        return result, None
    r, iters, tb = result
    return (r, iters), trace_summary(tb, iters)
