"""Flight recorder: a bounded ring buffer of structured events (ISSUE 10).

When the guard's escalation ladder exhausts, counters say *how many* times
each rung fired but not *in what order* or around which batches — the
post-failure question is always "what happened just before?". The flight
recorder answers it: every interesting host-side transition (batch applied,
engine chosen, rebuild fallback, quarantine, health trip, escalation rung,
audit, checkpoint, restore, SLO breach) appends one ``FlightEvent`` — a
monotonic timestamp, a dotted ``kind`` (same naming scheme as the span /
counter registry, DESIGN.md §14) and a small payload dict — into a fixed
ring. Old events are overwritten, never reallocated: memory is bounded, an
``emit`` is a lock + two list writes, and the recorder is cheap enough to
leave always-on (``benchmarks/bench_obs2.py`` holds the whole obs layer to
<2% of per-batch apply time).

The recorder is deliberately host-only and jit-free: events come from the
same call sites as the span registry, one per *decision*, never per
iteration (iteration telemetry is ``obs.trace``'s job).

Kill switch: ``REPRO_OBS_OFF=1`` (env, read at import; or
``set_obs_enabled(False)`` in-process) turns ``emit`` and the span
histograms into no-ops — the overhead baseline the bench measures against.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, NamedTuple, Optional

__all__ = ["FlightEvent", "FlightRecorder", "get_flight", "reset_flight",
           "obs_enabled", "set_obs_enabled"]

_ENABLED = os.environ.get("REPRO_OBS_OFF", "") not in ("1", "true", "yes")


def obs_enabled() -> bool:
    """True unless the always-on layer is switched off (``REPRO_OBS_OFF=1``
    or :func:`set_obs_enabled`). Gates flight emits and span histograms;
    spans/counters themselves (the v1 layer) are never gated."""
    return _ENABLED


def set_obs_enabled(on: bool) -> None:
    """In-process override of the ``REPRO_OBS_OFF`` kill switch (benches
    toggle it to measure the on/off delta inside one process)."""
    global _ENABLED
    _ENABLED = bool(on)


class FlightEvent(NamedTuple):
    """One recorded event: global sequence number, monotonic timestamp,
    dotted kind, payload dict (small, JSON-serializable values only)."""
    seq: int
    ts: float
    kind: str
    data: dict

    def as_dict(self) -> dict:
        return {"seq": self.seq, "ts": self.ts, "kind": self.kind,
                "data": self.data}


class FlightRecorder:
    """Bounded, thread-safe ring buffer of :class:`FlightEvent`.

    ``capacity`` is fixed at construction; the ``seq`` counter is global and
    never resets inside one recorder's lifetime, so ``dropped`` (events
    overwritten by wraparound) is exact and gaps in a tail are visible.
    """

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: List[Optional[FlightEvent]] = [None] * self.capacity
        self._seq = 0
        self._by_kind: Dict[str, int] = {}

    def emit(self, kind: str, **data) -> None:
        """Record one event (no-op under ``REPRO_OBS_OFF``)."""
        if not _ENABLED:
            return
        ts = time.monotonic()
        with self._lock:
            seq = self._seq
            self._seq = seq + 1
            self._ring[seq % self.capacity] = FlightEvent(seq, ts, kind, data)
            self._by_kind[kind] = self._by_kind.get(kind, 0) + 1

    def __len__(self) -> int:
        with self._lock:
            return min(self._seq, self.capacity)

    @property
    def total(self) -> int:
        """Events ever emitted (>= len(self) once the ring wrapped)."""
        with self._lock:
            return self._seq

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wraparound."""
        with self._lock:
            return max(0, self._seq - self.capacity)

    def events(self) -> List[FlightEvent]:
        """Chronological snapshot of the surviving window (oldest first)."""
        with self._lock:
            n = min(self._seq, self.capacity)
            start = self._seq - n
            out = [self._ring[i % self.capacity]
                   for i in range(start, self._seq)]
        return [e for e in out if e is not None]

    def tail(self, n: int) -> List[FlightEvent]:
        """The newest ``n`` events, chronological."""
        evs = self.events()
        return evs[-max(int(n), 0):]

    def summary(self) -> dict:
        """Small aggregate for reports: totals + per-kind counts."""
        with self._lock:
            return {"total": self._seq,
                    "dropped": max(0, self._seq - self.capacity),
                    "capacity": self.capacity,
                    "by_kind": dict(sorted(self._by_kind.items()))}

    def reset(self) -> None:
        with self._lock:
            self._ring = [None] * self.capacity
            self._seq = 0
            self._by_kind.clear()


_DEFAULT = FlightRecorder()


def get_flight() -> FlightRecorder:
    """The process-wide default recorder (mirrors ``spans.get_registry``)."""
    return _DEFAULT


def reset_flight() -> None:
    _DEFAULT.reset()
