"""Host-side spans and monotonic counters.

The device-side story is `obs.trace`; this module covers everything the
host does around the solves: snapshot maintenance phases, engine choice,
rebuild fallbacks, scatter traffic. A ``Registry`` aggregates

  * **spans** — named wall-clock sections (count / total / min / max), used
    as ``with registry.span("snapshot.device_refresh"): ...``. Spans may
    additionally emit a ``jax.profiler.TraceAnnotation`` (``annotate=True``)
    so the same names appear on the device timeline when a profiler trace
    is being captured — the hook the tentpole asks for around kernel
    dispatch; it is a no-op overhead-wise when no trace is active.
  * **counters** — monotonic ``inc(name, v)`` accumulators (in-place edits
    vs rebuild fallbacks, rows/tiles scattered, migrations, per-engine
    batch counts...).

One process-wide default registry keeps instrumentation call sites
import-light (`get_registry()`); tests and benches that need isolation can
``reset_registry()`` or construct their own.

Naming scheme (DESIGN.md §10): dotted paths, ``<subsystem>.<event>``, e.g.
``snapshot.apply``, ``snapshot.rebuild``, ``session.engine.compact``,
``kernels.stream_scatter.calls``.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, Optional

from .flight import obs_enabled
from .hist import Histogram

__all__ = ["SpanStats", "Registry", "Span", "get_registry", "reset_registry"]

try:  # optional: device-timeline annotation when a profiler trace is live
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - ancient jax
    _TraceAnnotation = None


class SpanStats:
    """Aggregate of one span name: count / total / min / max seconds."""

    __slots__ = ("count", "total_s", "min_s", "max_s")

    def __init__(self):
        self.count = 0
        self.total_s = 0.0
        self.min_s = float("inf")
        self.max_s = 0.0

    def add(self, dt: float) -> None:
        self.count += 1
        self.total_s += dt
        self.min_s = min(self.min_s, dt)
        self.max_s = max(self.max_s, dt)

    def as_dict(self) -> dict:
        return {"count": self.count, "total_s": self.total_s,
                "min_s": self.min_s, "max_s": self.max_s,
                "mean_s": self.total_s / max(self.count, 1)}


class Registry:
    """Thread-safe span/counter sink; cheap enough to leave always-on."""

    def __init__(self):
        self._lock = threading.Lock()
        self._spans: Dict[str, SpanStats] = {}
        self._counters: Dict[str, int] = {}
        #: per-span latency histograms (obs.hist) — the p50/p95/p99 source;
        #: fed alongside SpanStats unless REPRO_OBS_OFF gates them off
        self._hists: Dict[str, Histogram] = {}

    # -- counters ------------------------------------------------------------

    def inc(self, name: str, v: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(v)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    # -- spans ---------------------------------------------------------------

    @contextmanager
    def span(self, name: str, annotate: bool = False):
        ann = (_TraceAnnotation(name) if annotate and
               _TraceAnnotation is not None else None)
        if ann is not None:
            ann.__enter__()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            if ann is not None:
                ann.__exit__(None, None, None)
            with self._lock:
                st = self._spans.get(name)
                if st is None:
                    st = self._spans[name] = SpanStats()
                st.add(dt)
                if obs_enabled():
                    h = self._hists.get(name)
                    if h is None:
                        h = self._hists[name] = Histogram()
                    h.add(dt)

    def span_stats(self, name: str) -> Optional[SpanStats]:
        with self._lock:
            return self._spans.get(name)

    def span_hist(self, name: str) -> Optional[Histogram]:
        """The span's latency histogram (None before its first timed pass
        or when the always-on layer is off)."""
        with self._lock:
            return self._hists.get(name)

    def record_hist(self, name: str, seconds: float) -> None:
        """Feed one latency sample into ``name``'s histogram without timing
        a span (callers that already hold the wall-clock, e.g. per-batch
        session accounting)."""
        if not obs_enabled():
            return
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.add(seconds)

    # -- export --------------------------------------------------------------

    def report(self) -> dict:
        """{"spans": {name: {...}}, "counters": {name: n}} snapshot. Span
        entries carry p50_s/p95_s/p99_s from the attached histogram when
        one exists (always-on layer enabled)."""
        with self._lock:
            spans = {}
            for k, v in sorted(self._spans.items()):
                d = v.as_dict()
                h = self._hists.get(k)
                if h is not None and h.count:
                    hd = h.as_dict()
                    d.update(p50_s=hd["p50_s"], p95_s=hd["p95_s"],
                             p99_s=hd["p99_s"])
                spans[k] = d
            return {
                "spans": spans,
                "counters": dict(sorted(self._counters.items())),
            }

    def reset(self) -> None:
        with self._lock:
            self._spans.clear()
            self._counters.clear()
            self._hists.clear()


class Span:
    """`with Span("name"):` against the default registry — the sugar the
    instrumentation call sites use."""

    def __init__(self, name: str, annotate: bool = False,
                 registry: Optional[Registry] = None):
        self.name = name
        self._cm = (registry or get_registry()).span(name, annotate=annotate)

    def __enter__(self):
        return self._cm.__enter__()

    def __exit__(self, *exc):
        return self._cm.__exit__(*exc)


_DEFAULT = Registry()


def get_registry() -> Registry:
    return _DEFAULT


def reset_registry() -> None:
    _DEFAULT.reset()
