"""Structured sinks: the machine-readable side of every benchmark run.

``RunReport`` collects benchmark records (name, min/mean/std timing, parsed
derived metrics, optional iteration-trace summary), a span/counter snapshot
from the default `obs.spans` registry, and environment provenance, then
serializes to

  * one JSON document  — ``BENCH_obs.json``, the artifact `benchmarks.run`
    writes next to its CSV and `repro.obs.check` diffs, and
  * JSONL             — one object per line (header + one per benchmark),
    the append-friendly form for long-running collectors.

Schema ``repro.obs/bench-v2`` (validated by `validate_report`):

  {"schema": "repro.obs/bench-v2", "name": ..., "created_unix": ...,
   "env": {"jax": ..., "backend": ..., "x64": ...},
   "spans": {...}, "counters": {...}, "flight": {...},
   "benchmarks": [
     {"name": str, "us_min": float, "us_mean": float, "us_std": float,
      "us_p50": float?, "us_p95": float?, "us_p99": float?, "us_max": float?,
      "derived": {str: str|float}, "trace": {...}|null}, ...]}

v2 over v1 (ISSUE 10): span entries carry p50_s/p95_s/p99_s from the
registry histograms, benchmark records may carry ``us_p50/us_p95/us_p99``
tail-latency columns (present when the bench supplied per-sample data),
and the header gains a ``flight`` recorder summary. ``load_report`` and
``validate_report`` still accept v1 documents, so the gate can diff a v2
run against a v1 baseline (percentile columns simply absent).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import List, Optional

__all__ = ["SCHEMA", "SCHEMA_V1", "RunReport", "load_report",
           "validate_report", "parse_derived"]

SCHEMA_V1 = "repro.obs/bench-v1"
SCHEMA = "repro.obs/bench-v2"
SCHEMAS = (SCHEMA, SCHEMA_V1)

#: optional per-record tail-latency columns (microseconds)
PCT_KEYS = ("us_p50", "us_p95", "us_p99", "us_max")


def parse_derived(derived: str) -> dict:
    """'k1=v1;k2=v2' (the CSV derived column) -> dict, numbers coerced."""
    out = {}
    for part in filter(None, derived.split(";")):
        if "=" not in part:
            out[part] = True
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v) if any(c in v for c in ".eE") or \
                v.lstrip("+-").isdigit() else v
        except ValueError:
            out[k] = v
    return out


def _env() -> dict:
    try:
        import jax
        return {"jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "x64": bool(jax.config.read("jax_enable_x64"))}
    except Exception:  # pragma: no cover - report must never kill a bench
        return {}


@dataclasses.dataclass
class RunReport:
    """One benchmark run's structured output."""
    name: str = "bench"
    created_unix: float = dataclasses.field(default_factory=time.time)
    env: dict = dataclasses.field(default_factory=_env)
    benchmarks: List[dict] = dataclasses.field(default_factory=list)
    spans: dict = dataclasses.field(default_factory=dict)
    counters: dict = dataclasses.field(default_factory=dict)
    flight: dict = dataclasses.field(default_factory=dict)

    def add(self, name: str, *, us_min: float, us_mean: float = None,
            us_std: float = None, us_p50: float = None, us_p95: float = None,
            us_p99: float = None, us_max: float = None,
            derived: Optional[dict] = None,
            trace: Optional[dict] = None) -> None:
        rec = {
            "name": name,
            "us_min": float(us_min),
            "us_mean": float(us_min if us_mean is None else us_mean),
            "us_std": float(0.0 if us_std is None else us_std),
            "derived": derived or {},
            "trace": trace,
        }
        for k, v in (("us_p50", us_p50), ("us_p95", us_p95),
                     ("us_p99", us_p99), ("us_max", us_max)):
            if v is not None:
                rec[k] = float(v)
        self.benchmarks.append(rec)

    def attach_registry(self, registry=None) -> None:
        """Snapshot the span/counter registry into the report."""
        if registry is None:
            from .spans import get_registry
            registry = get_registry()
        rep = registry.report()
        self.spans = rep["spans"]
        self.counters = rep["counters"]

    def attach_flight(self, recorder=None) -> None:
        """Snapshot the flight recorder's summary into the report."""
        if recorder is None:
            from .flight import get_flight
            recorder = get_flight()
        self.flight = recorder.summary()

    def to_dict(self) -> dict:
        return {"schema": SCHEMA, "name": self.name,
                "created_unix": self.created_unix, "env": self.env,
                "spans": self.spans, "counters": self.counters,
                "flight": self.flight, "benchmarks": self.benchmarks}

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, allow_nan=False)
            f.write("\n")

    def write_jsonl(self, path: str) -> None:
        """Header line (everything but benchmarks) + one line per record."""
        head = self.to_dict()
        records = head.pop("benchmarks")
        head["kind"] = "header"
        with open(path, "w") as f:
            f.write(json.dumps(head, allow_nan=False) + "\n")
            for rec in records:
                f.write(json.dumps({"kind": "benchmark", **rec},
                                   allow_nan=False) + "\n")


def load_report(path: str) -> dict:
    """Load either serialized form back into a schema dict."""
    with open(path) as f:
        first = f.readline()
        doc = json.loads(first) if first.lstrip().startswith('{"') and \
            '"kind": "header"' in first else None
        if doc is not None:  # JSONL
            doc.pop("kind", None)
            doc["benchmarks"] = []
            for line in f:
                if not line.strip():
                    continue
                rec = json.loads(line)
                if rec.pop("kind", None) == "benchmark":
                    doc["benchmarks"].append(rec)
            return doc
        f.seek(0)
        return json.load(f)


def validate_report(doc: dict) -> List[str]:
    """Schema check; returns a list of problems (empty = valid)."""
    errs = []
    if not isinstance(doc, dict):
        return ["report is not an object"]
    if doc.get("schema") not in SCHEMAS:
        errs.append(f"schema not in {SCHEMAS!r}: {doc.get('schema')!r}")
    benches = doc.get("benchmarks")
    if not isinstance(benches, list):
        return errs + ["benchmarks is not a list"]
    for i, b in enumerate(benches):
        where = f"benchmarks[{i}]"
        if not isinstance(b, dict):
            errs.append(f"{where} is not an object")
            continue
        if not isinstance(b.get("name"), str) or not b.get("name"):
            errs.append(f"{where}.name missing")
        for k in ("us_min", "us_mean", "us_std"):
            if not isinstance(b.get(k), (int, float)):
                errs.append(f"{where}.{k} missing or non-numeric")
        for k in PCT_KEYS:  # v2 optional tail-latency columns
            if k in b and not isinstance(b[k], (int, float)):
                errs.append(f"{where}.{k} non-numeric")
        tr = b.get("trace")
        if tr is not None:
            if not isinstance(tr, dict):
                errs.append(f"{where}.trace is not an object")
            else:
                for k in ("engine", "iters", "linf_delta", "frontier"):
                    if k not in tr:
                        errs.append(f"{where}.trace.{k} missing")
    return errs
