"""Regression gate: diff two ``BENCH_obs.json`` reports, fail on slowdowns.

    python -m repro.obs.check CURRENT.json BASELINE.json [--threshold 0.10]
    python -m repro.obs.check CURRENT.json --against seed [--threshold ...]

Exit codes: 0 clean, 1 regression (or schema failure / missing benchmark),
2 usage / IO error.

A benchmark regresses when ``us_mean`` grows by more than ``--threshold``
(fraction; default 0.10 = +10%) over the baseline, subject to a
``--min-us`` floor (default 50µs: sub-floor benches are timer noise).
Schema-v2 reports additionally gate ``us_p99`` where both sides carry it —
a tail regression fails even when the mean holds. Benchmarks present in
the baseline but absent from the current report fail the gate too — a
silently dropped bench is how regressions hide.

``--json`` replaces the human table with one machine-readable verdict
document ({"verdict", "failures", "benchmarks": [{name, status, ratio}]})
so CI can annotate the PR without parsing log text; exit codes unchanged.

``--against seed`` resolves the committed machine-reference baseline
(``benchmarks/seed/BENCH_obs_seed.json``, override via ``$REPRO_BENCH_SEED``).
Cross-machine timing is not comparable at 10%, so CI pairs ``--against
seed`` with a catastrophic-only threshold (see .github/workflows/ci.yml);
the strict default is for same-machine before/after runs. A missing seed
baseline passes with a warning unless ``--strict`` (first run bootstraps).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

from .report import load_report, validate_report

__all__ = ["main", "compare"]


def _seed_path() -> Path:
    env = os.environ.get("REPRO_BENCH_SEED")
    if env:
        return Path(env)
    # src/repro/obs/check.py -> repo root is three levels above src/
    root = Path(__file__).resolve().parents[3]
    return root / "benchmarks" / "seed" / "BENCH_obs_seed.json"


def compare(current: dict, baseline: dict, threshold: float,
            min_us: float, only=()) -> tuple:
    """Returns (failures, lines, results): failure strings, a human diff
    table, and per-bench machine-readable verdicts (``--json``).

    ``only`` (name prefixes) restricts the gate to matching benchmarks on
    both sides — for partial runs that exercised a subset of the suite
    (e.g. test.sh gating just the frontier rows).

    Two gated metrics per benchmark: ``us_mean`` always, and ``us_p99``
    when BOTH reports carry it (schema v2) — a tail regression fails the
    gate even when the mean holds (the paper's claims are distributions,
    not means)."""
    failures, lines, results = [], [], []
    keep = ((lambda n: any(n.startswith(p) for p in only)) if only
            else (lambda n: True))
    cur = {b["name"]: b for b in current.get("benchmarks", [])
           if keep(b["name"])}
    base = {b["name"]: b for b in baseline.get("benchmarks", [])
            if keep(b["name"])}
    for name, b in sorted(base.items()):
        c = cur.get(name)
        if c is None:
            failures.append(f"missing benchmark: {name}")
            lines.append(f"  {name:<48} MISSING from current report")
            results.append({"name": name, "status": "missing"})
            continue
        b_us, c_us = float(b["us_mean"]), float(c["us_mean"])
        res = {"name": name, "status": "ok", "base_us": b_us, "cur_us": c_us,
               "ratio": c_us / max(b_us, 1e-9)}
        if b_us < min_us and c_us < min_us:
            lines.append(f"  {name:<48} {b_us:>10.1f} -> {c_us:>10.1f} us"
                         f"  (below {min_us:g}us floor, skipped)")
            res["status"] = "skipped"
            results.append(res)
            continue
        rel = (c_us - b_us) / max(b_us, 1e-9)
        mark = ""
        if rel > threshold:
            mark = "  REGRESSION"
            res["status"] = "regression"
            failures.append(
                f"{name}: {b_us:.1f}us -> {c_us:.1f}us (+{rel * 100:.1f}% "
                f"> {threshold * 100:.0f}%)")
        lines.append(f"  {name:<48} {b_us:>10.1f} -> {c_us:>10.1f} us"
                     f"  ({rel * +100:+.1f}%){mark}")
        if ("us_p99" in b and "us_p99" in c
                and float(b["us_p99"]) >= min_us):
            bp, cp = float(b["us_p99"]), float(c["us_p99"])
            relp = (cp - bp) / max(bp, 1e-9)
            res.update(base_p99_us=bp, cur_p99_us=cp,
                       p99_ratio=cp / max(bp, 1e-9))
            markp = ""
            if relp > threshold:
                markp = "  REGRESSION"
                res["status"] = "regression"
                failures.append(
                    f"{name}: p99 {bp:.1f}us -> {cp:.1f}us "
                    f"(+{relp * 100:.1f}% > {threshold * 100:.0f}%)")
            lines.append(f"  {name + ' (p99)':<48} {bp:>10.1f} -> "
                         f"{cp:>10.1f} us  ({relp * +100:+.1f}%){markp}")
        results.append(res)
    extra = sorted(set(cur) - set(base))
    for name in extra:
        lines.append(f"  {name:<48} (new, no baseline)")
        results.append({"name": name, "status": "new",
                        "cur_us": float(cur[name]["us_mean"])})
    return failures, lines, results


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.check",
        description="Diff two BENCH_obs.json reports; fail on regressions.")
    p.add_argument("current", help="current BENCH_obs.json")
    p.add_argument("baseline", nargs="?", help="baseline BENCH_obs.json")
    p.add_argument("--against", choices=["seed"],
                   help="use the committed seed baseline")
    p.add_argument("--threshold", type=float, default=0.10,
                   help="max allowed us_mean growth fraction (default 0.10)")
    p.add_argument("--min-us", type=float, default=50.0,
                   help="ignore benches faster than this (timer noise)")
    p.add_argument("--strict", action="store_true",
                   help="fail (not warn) when the baseline file is missing")
    p.add_argument("--only", action="append", default=[],
                   help="gate only benchmarks whose name starts with this "
                        "prefix (repeatable); default: all")
    p.add_argument("--json", action="store_true",
                   help="print a machine-readable verdict document to "
                        "stdout instead of the table (exit codes unchanged)")
    args = p.parse_args(argv)

    def verdict(status, *, failures=(), results=(), error=None):
        """Emit the --json document (stdout); human output stays as-is."""
        if args.json:
            doc = {"verdict": status, "current": args.current,
                   "baseline": str(base_path) if base_path else None,
                   "threshold": args.threshold,
                   "failures": list(failures), "benchmarks": list(results)}
            if error is not None:
                doc["error"] = error
            print(json.dumps(doc, indent=1))

    base_path = None
    if (args.baseline is None) == (args.against is None):
        p.error("give exactly one of BASELINE or --against seed")
    base_path = Path(args.baseline) if args.baseline else _seed_path()

    try:
        current = load_report(args.current)
    except (OSError, ValueError) as e:
        verdict("error", error=f"cannot read current report: {e}")
        print(f"error: cannot read current report: {e}", file=sys.stderr)
        return 2
    errs = validate_report(current)
    if errs:
        verdict("fail", failures=[f"schema: {e}" for e in errs])
        print("current report fails schema validation:", file=sys.stderr)
        for e in errs:
            print(f"  {e}", file=sys.stderr)
        return 1

    if not base_path.exists():
        msg = f"baseline not found: {base_path}"
        if args.strict:
            verdict("error", error=msg)
            print(f"error: {msg}", file=sys.stderr)
            return 2
        verdict("pass", failures=[], results=[])
        print(f"warning: {msg} — nothing to gate against (bootstrap run)")
        return 0
    try:
        baseline = load_report(str(base_path))
    except (OSError, ValueError) as e:
        verdict("error", error=f"cannot read baseline: {e}")
        print(f"error: cannot read baseline: {e}", file=sys.stderr)
        return 2

    failures, lines, results = compare(current, baseline, args.threshold,
                                       args.min_us, only=tuple(args.only))
    if args.json:
        verdict("fail" if failures else "pass", failures=failures,
                results=results)
        return 1 if failures else 0
    print(f"repro.obs.check: {args.current} vs {base_path} "
          f"(threshold +{args.threshold * 100:.0f}%)")
    for ln in lines:
        print(ln)
    if failures:
        print(f"\nFAIL: {len(failures)} regression(s)")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nOK: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
