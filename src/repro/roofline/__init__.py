from .analysis import RooflineReport, analyze, collective_bytes, model_flops, count_params
from .analytic import StepCost, cost_for, train_cost, prefill_cost, decode_cost
__all__ = ["RooflineReport", "analyze", "collective_bytes", "model_flops",
           "count_params", "StepCost", "cost_for", "train_cost",
           "prefill_cost", "decode_cost"]
