"""Roofline term extraction from a compiled (dry-run) artifact.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis: we parse the post-SPMD optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops. Sizes are per-program (i.e. per-device) in SPMD HLO,
which is exactly the per-chip number the roofline wants.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from ..launch.mesh import HW

__all__ = ["RooflineReport", "collective_bytes", "analyze", "model_flops"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' -> bytes."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _result_shapes(lhs: str) -> list[str]:
    """Result type of an HLO instruction line (handles tuples)."""
    # '%x = (f32[2,4]{...}, f32[4]{...}) all-reduce(...)' or
    # '%x = f32[2,4]{...} all-reduce(...)'
    m = re.search(r"=\s*\(([^)]*)\)\s*[\w-]+\(", lhs)
    if m:
        return [s for s in m.group(1).split(", ") if "[" in s]
    m = re.search(r"=\s*([\w\[\],{}]+)\s*[\w-]+\(", lhs)
    return [m.group(1)] if m else []


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind byte totals from optimized HLO (per device)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in _COLLECTIVES:
            # match op name before '(' e.g. ' all-reduce(' / ' all-gather-start('
            if re.search(rf"=.*\s{kind}(-start)?\(", ls):
                for s in _result_shapes(ls):
                    out[kind] += _shape_bytes(s)
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float            # per device
    coll_breakdown: dict
    model_flops: Optional[float] = None
    per_device_mem: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * HW.PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HW.HBM_BW)

    @property
    def t_collective(self) -> float:
        # coll_bytes is already per-device; each chip drives its own links
        return self.coll_bytes / HW.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the step would achieve if the dominant term were
        the runtime: t_compute / max(all terms)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t > 0 else 0.0

    def row(self) -> str:
        u = self.useful_ratio
        return (f"{self.name:46s} {self.t_compute*1e3:10.2f} "
                f"{self.t_memory*1e3:10.2f} {self.t_collective*1e3:10.2f} "
                f"{self.bottleneck:10s} {self.roofline_fraction:6.2f} "
                f"{'' if u is None else f'{u:6.2f}'}")


def analyze(name: str, compiled, chips: int,
            model_flops_val: Optional[float] = None) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    # cost_analysis flops on a partitioned module are per-device on CPU
    # backend; normalize to GLOBAL flops for the compute term.
    return RooflineReport(
        name=name, chips=chips, hlo_flops=flops * chips, hlo_bytes=byts * chips,
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        model_flops=model_flops_val, per_device_mem=mem)


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6·N·D for training (N params, D tokens); 2·N·D for inference.
# MoE: N = active params.
# ---------------------------------------------------------------------------

def count_params(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the ArchConfig (analytic)."""
    d, f, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    pre, pat, reps, suf = cfg.layer_kinds()
    kinds = list(pre) + list(pat) * reps + list(suf)
    total = active = 2 * V * d  # embed + unembed
    for kind in kinds:
        if kind.startswith("mla"):
            m = cfg.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            attn = (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * cfg.n_heads
                    * (m.qk_nope_dim + m.v_head_dim)
                    + cfg.n_heads * m.v_head_dim * d)
        elif kind in ("rwkv", "rec"):
            if kind == "rwkv":
                attn = 5 * d * d + 2 * d * 64 + d * 32 * 6
            else:
                w = cfg.rec.lru_width or d
                attn = 2 * d * w + 2 * w * w + w * d + cfg.rec.conv_width * w
        else:
            hd = cfg.hd
            attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
                + cfg.n_heads * hd * d
        gated = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        if kind.endswith("_moe"):
            e = cfg.moe
            ffn_total = e.n_experts * gated * d * e.d_ff_expert \
                + d * e.n_experts + e.n_shared * gated * d * e.d_ff_expert
            ffn_active = (e.top_k + e.n_shared) * gated * d * e.d_ff_expert \
                + d * e.n_experts
        elif kind == "rwkv":
            ffn_total = ffn_active = 2 * d * f + d * d
        elif kind == "rec":
            ffn_total = ffn_active = gated * d * f
        else:
            ffn_total = ffn_active = gated * d * f
        total += attn + ffn_total
        active += attn + ffn_active
    return float(total), float(active)


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train; 2·N_active·D_new for prefill/decode."""
    _, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: 1 token per sequence
