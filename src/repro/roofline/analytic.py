"""Analytic (trip-count-aware) roofline terms per (arch × shape × mesh).

Why analytic: XLA-CPU ``cost_analysis`` counts every loop body ONCE (verified
by calibration in EXPERIMENTS.md §Dry-run: a scan of 8 identical matmuls
reports the flops of 1), and ``memory_analysis.temp_size_in_bytes`` sums
nested-while temps without cross-iteration reuse (a 16-microbatch scan
reports 16× one iteration). The dry-run therefore proves *shardability and
compilability* and provides the collective inventory; the roofline *numbers*
come from this module's explicit napkin math, which multiplies every loop by
its real trip count. Cross-checks against the compiled artifact are recorded
in EXPERIMENTS.md.

All byte counts are PER DEVICE; flop counts are GLOBAL (the report divides by
chip count).
"""
from __future__ import annotations

import dataclasses
import math

from ..configs.base import ArchConfig
from .analysis import count_params

__all__ = ["StepCost", "train_cost", "prefill_cost", "decode_cost",
           "cost_for"]

_ADAM_STATE_B = 8       # m+v f32
_ADAFACTOR_STATE_B = 0.1


@dataclasses.dataclass
class StepCost:
    flops: float               # global
    hbm_bytes: float           # per device
    coll_bytes: float          # per device (through its ICI links)
    mem_bytes: float           # per-device residency (params/opt/cache/act)
    notes: dict


def _mesh_sizes(mesh_shape: dict, cfg=None):
    mp = mesh_shape.get("model", 1)
    dp = 1
    for k, v in mesh_shape.items():
        if k != "model":
            dp *= v
    if cfg is not None and getattr(cfg, "pure_dp", False):
        dp, mp = dp * mp, 1
    return dp, mp


def _layer_list(cfg: ArchConfig):
    pre, pat, reps, suf = cfg.layer_kinds()
    return list(pre) + list(pat) * reps + list(suf)


def _attn_flops_fwd(cfg: ArchConfig, kind: str, B: int, S: int,
                    T: int | None = None) -> float:
    """Score+PV einsum flops for the chunked schedule (full masked rectangle
    — the causal-optimal half is a known 2x headroom, noted in §Perf)."""
    T = S if T is None else T
    if kind.startswith("mla"):
        m = cfg.mla
        qk, vd = m.qk_nope_dim + m.qk_rope_dim, m.v_head_dim
        return 2.0 * B * S * T * cfg.n_heads * (qk + vd)
    if kind == "rwkv":
        C = cfg.rec.chunk
        H = cfg.d_model // cfg.rec.head_dim
        dk = cfg.rec.head_dim
        # per chunk: scores C·C·dk + out C·C·dk + carry C·dk·dk, × S/C chunks
        return 2.0 * B * (S / C) * H * (2 * C * C * dk + 2 * C * dk * dk)
    if kind == "rec":
        w = cfg.rec.lru_width or cfg.d_model
        return 10.0 * B * S * w          # gates + scan (element-wise)
    eff_T = min(T, cfg.window) if kind == "attn_local" and cfg.window else T
    return 4.0 * B * S * eff_T * cfg.n_heads * cfg.hd


def _linear_flops_fwd(cfg: ArchConfig, tokens: float) -> float:
    """2·N·tokens over matmul params (excludes attention quadratic part)."""
    _, active = count_params(cfg)
    return 2.0 * active * tokens


def _param_local_bytes(cfg: ArchConfig, dp: int, mp: int) -> float:
    """Per-device parameter bytes. Dense/attn params shard over mp (where
    divisible — approximate with full mp); MoE experts additionally over dp."""
    total, _ = count_params(cfg)
    if cfg.moe:
        e = cfg.moe
        kinds = _layer_list(cfg)
        n_moe = sum(1 for k in kinds if k.endswith("_moe"))
        gated = 3
        expert_params = n_moe * e.n_experts * gated * cfg.d_model \
            * e.d_ff_expert
        rest = total - expert_params
        return 2.0 * (expert_params / (dp * mp) + rest / mp)
    return 2.0 * total / mp


def _act_io_per_layer(cfg: ArchConfig, tok_local: float) -> float:
    """HBM traffic of one layer forward on one device (bf16), coarse:
    ~14 activation-tensor reads/writes of [tok, d] plus mixer temps."""
    return 14.0 * 2.0 * tok_local * cfg.d_model


def train_cost(cfg: ArchConfig, B: int, S: int, mesh_shape: dict) -> StepCost:
    dp, mp = _mesh_sizes(mesh_shape, cfg)
    chips = dp * mp
    tokens = float(B) * S
    n_micro = max(1, B // cfg.microbatch)
    mb_tok = tokens / n_micro
    tok_local = mb_tok / dp
    L = cfg.n_layers
    kinds = _layer_list(cfg)

    lin_fwd = _linear_flops_fwd(cfg, tokens)
    attn_fwd = sum(_attn_flops_fwd(cfg, k, B / n_micro, S) for k in kinds) \
        * n_micro
    # full remat: fwd + replay + bwd(2x)  =>  4x fwd
    flops = 4.0 * (lin_fwd + attn_fwd)

    pb = _param_local_bytes(cfg, dp, mp)
    total, _ = count_params(cfg)
    acc_b = 2 if cfg.grad_accum_dtype == "bfloat16" else 4
    state_b = _ADAFACTOR_STATE_B if cfg.optimizer == "adafactor" \
        else _ADAM_STATE_B
    gdiv = (dp * mp if cfg.pure_dp else dp) if cfg.zero1 else 1  # ZeRO-1
    bdiv = mp if cfg.seq_parallel else 1            # SP: boundaries over mp
    hbm = 0.0
    hbm += 3.0 * pb * n_micro                       # weight reads fwd/replay/bwd
    hbm += 2.0 * (pb / 2 * acc_b / gdiv) * n_micro  # grad accum read+write
    hbm += pb + (pb / 2 / gdiv) * (2 * state_b + acc_b) + pb   # optimizer
    hbm += sum(_act_io_per_layer(cfg, tok_local) for _ in range(L)) \
        * n_micro * 2.0                             # fwd + replay (bwd ~ fwd)

    # collectives per device
    coll = 0.0
    # grad sync over dp: all-reduce (2x) or reduce-scatter+all-gather w/ ZeRO
    coll += 2.0 * (pb / 2 * acc_b)
    # model-parallel activation psums: 2 per layer, fwd+replay+bwd; with SP
    # each psum pair becomes all-gather+reduce-scatter (half the bytes)
    sp_f = 0.5 if cfg.seq_parallel else 1.0
    coll += (0.0 if mp == 1 else
             3.0 * 2.0 * L * 2.0 * 2.0 * tok_local * cfg.d_model * sp_f) \
        * n_micro
    if cfg.moe:
        e = cfg.moe
        n_moe = sum(1 for k in kinds if k.endswith("_moe"))
        # EP all-to-all: each device ships its local routed tokens out and
        # the results back (dispatch + combine), fwd + replay + bwd
        disp_b = 1.0 if e.dispatch_dtype != "bfloat16" else 2.0
        # dispatch leg (disp_b bytes) + combine leg (bf16)
        moe_bytes = (tok_local * e.top_k * e.capacity_factor
                     * cfg.d_model) * (disp_b + 2.0)
        if e.n_groups and e.group_top:
            # node-limited routing: destinations span group_top/n_groups of
            # the EP axis -> proportionally fewer contended torus hops
            # (egress volume is unchanged; this models link sharing)
            moe_bytes *= e.group_top / e.n_groups
        coll += 3.0 * n_moe * moe_bytes * n_micro
    mem = pb + (pb / 2 / gdiv) * (acc_b + state_b) \
        + 2.0 * 2.0 * tok_local * cfg.d_model * L / bdiv  # saved boundaries
    return StepCost(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    mem_bytes=mem,
                    notes={"n_micro": n_micro, "dp": dp, "mp": mp,
                           "param_local_gb": pb / 1e9})


def prefill_cost(cfg: ArchConfig, B: int, S: int, mesh_shape: dict
                 ) -> StepCost:
    dp, mp = _mesh_sizes(mesh_shape, cfg)
    tokens = float(B) * S
    tok_local = tokens / dp
    kinds = _layer_list(cfg)
    flops = _linear_flops_fwd(cfg, tokens) \
        + sum(_attn_flops_fwd(cfg, k, B, S) for k in kinds)
    pb = _param_local_bytes(cfg, dp, mp)
    hbm = pb + sum(_act_io_per_layer(cfg, tok_local) for _ in kinds)
    coll = 0.0 if mp == 1 else 2.0 * len(kinds) * 2.0 * 2.0 * tok_local \
        * cfg.d_model
    if cfg.moe:
        n_moe = sum(1 for k in kinds if k.endswith("_moe"))
        coll += n_moe * 2.0 * 2.0 * tokens / dp * cfg.d_model
    return StepCost(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    mem_bytes=pb + 2 * 2 * tok_local * cfg.d_model,
                    notes={"dp": dp, "mp": mp})


def _cache_local_bytes(cfg: ArchConfig, B: int, T: int, dp: int, mp: int
                       ) -> float:
    kinds = _layer_list(cfg)
    cb = 2 if cfg.kv_cache_dtype != "int8" else 1
    total = 0.0
    for k in kinds:
        if k.startswith("mla"):
            m = cfg.mla
            total += B * T * (m.kv_lora_rank + m.qk_rope_dim) * cb
        elif k == "rwkv":
            H = cfg.d_model // cfg.rec.head_dim
            total += B * H * cfg.rec.head_dim ** 2 * 4
        elif k == "rec":
            w = cfg.rec.lru_width or cfg.d_model
            total += B * w * 4 * cfg.rec.conv_width
        else:
            Tk = min(T, cfg.window) if k == "attn_local" and cfg.window else T
            total += 2 * B * Tk * cfg.n_kv_heads * cfg.hd * cb
    bdiv = dp if B % dp == 0 else 1
    if cfg.shard_cache_t:
        bdiv *= mp
    return total / bdiv


def decode_cost(cfg: ArchConfig, B: int, T: int, mesh_shape: dict) -> StepCost:
    dp, mp = _mesh_sizes(mesh_shape, cfg)
    kinds = _layer_list(cfg)
    flops = _linear_flops_fwd(cfg, float(B)) \
        + sum(_attn_flops_fwd(cfg, k, B, 1, T=T) for k in kinds)
    pb = _param_local_bytes(cfg, dp, mp)
    cache = _cache_local_bytes(cfg, B, T, dp, mp)
    hbm = pb + cache          # read all weights + whole cache, write 1 slot
    coll = 0.0 if mp == 1 else 2.0 * len(kinds) * 2.0 * 2.0 \
        * (B / (dp if B % dp == 0 else 1)) * cfg.d_model
    return StepCost(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    mem_bytes=pb + cache,
                    notes={"dp": dp, "mp": mp, "cache_local_gb": cache / 1e9})


def cost_for(cfg: ArchConfig, shape, mesh_shape: dict) -> StepCost:
    if shape.kind == "train":
        return train_cost(cfg, shape.global_batch, shape.seq_len, mesh_shape)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape.global_batch, shape.seq_len,
                            mesh_shape)
    return decode_cost(cfg, shape.global_batch, shape.seq_len, mesh_shape)
