"""Attention: GQA (+bias/qk-norm/softcap/local-window), MLA, KV caches.

Full-sequence attention is computed block-by-block with an online-softmax
(flash-style) schedule in pure JAX — memory O(S·chunk) per head group — so
prefill_32k lowers without materializing S² scores. Decode is a single-query
attention over the cache with optional int8 quantized storage.

On TPU the chunked schedule is the natural Pallas candidate; we keep it in
jnp so the multi-pod dry-run compiles on any backend (DESIGN.md §2), and the
blocking already matches MXU-friendly tiles (chunk × head_dim multiples of 128).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init, mrope_apply, rmsnorm, softcap

__all__ = ["attn_init", "attn_apply", "attn_decode", "mla_init", "mla_apply",
           "mla_decode", "init_kv_cache", "init_mla_cache",
           "chunked_attention", "quantize_kv", "dequantize_kv"]

NEG_INF = -2.0 ** 30  # large-finite: avoids NaN rows for fully-masked blocks


# ---------------------------------------------------------------------------
# Flash-style chunked causal attention (shared by all attention kinds)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, chunk: int, window: Optional[int] = None,
                      cap: Optional[float] = None, q_offset=0):
    """q [B,S,H,D]; k,v [B,T,K,D] with H = G*K (GQA). Causal; optional
    sliding window and tanh soft-cap. Returns [B,S,H,D]."""
    B, S, H, D = q.shape
    Dv = v.shape[-1]                 # MLA: qk dim != v dim
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    nq = max(1, S // chunk)
    cq = S // nq
    nk = max(1, T // chunk)
    ck = T // nk
    qb = q.reshape(B, nq, cq, K, G, D)

    def one_q_block(args):
        qi, i = args                                  # [B,cq,K,G,D]
        qpos = q_offset + i * cq + jnp.arange(cq)

        def kv_step(carry, j):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, cap)
            kpos = j * ck + jnp.arange(ck)
            allow = kpos[None, :] <= qpos[:, None]
            if window is not None:
                allow &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(allow[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, K, G, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.astype(q.dtype)

    outs = jax.lax.map(one_q_block, (qb.transpose(1, 0, 2, 3, 4, 5),
                                     jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, Dv)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attn_init(rng, cfg, dtype):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {"wq": dense_init(ks[0], (d, H, hd), in_axis_size=d, dtype=dtype),
         "wk": dense_init(ks[1], (d, K, hd), in_axis_size=d, dtype=dtype),
         "wv": dense_init(ks[2], (d, K, hd), in_axis_size=d, dtype=dtype),
         "wo": dense_init(ks[3], (H, hd, d), in_axis_size=H * hd, dtype=dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    if cfg.qk_norm:
        p["qn"] = jnp.zeros((hd,), dtype)
        p["kn"] = jnp.zeros((hd,), dtype)
    return p


def _qkv(x, p, cfg, positions):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q, k = rmsnorm(q, p["qn"]), rmsnorm(k, p["kn"])
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = mrope_apply(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = mrope_apply(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attn_apply(x, p, cfg, kind: str, positions):
    """Full-sequence (train / prefill). Returns (out, (k, v) for caching)."""
    q, k, v = _qkv(x, p, cfg, positions)
    window = cfg.window if kind == "attn_local" else None
    o = chunked_attention(q, k, v, chunk=cfg.attn_chunk, window=window,
                          cap=cfg.attn_softcap)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), (k, v)


def attn_decode(x, p, cfg, kind: str, cache, pos):
    """One-token decode. x [B,1,d]; cache {"k","v"} [B,T,K,hd] (+scales if
    int8); pos scalar int32 = current position. Local kinds roll mod window."""
    B = x.shape[0]
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(pos, (B, 3, 1))
    else:
        positions = jnp.broadcast_to(pos, (B, 1))
    q, k, v = _qkv(x, p, cfg, positions)
    T = cache["k"].shape[1]
    slot = pos % T if kind == "attn_local" else pos  # rolling window slot
    kq, ks_ = quantize_kv(k, cache)
    vq, vs_ = quantize_kv(v, cache)
    new_cache = dict(cache)
    new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot,
                                                         axis=1)
    new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot,
                                                         axis=1)
    if "k_scale" in cache:
        new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks_, slot, axis=1)
        new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs_, slot, axis=1)
    kf = dequantize_kv(new_cache["k"], new_cache.get("k_scale"), q.dtype)
    vf = dequantize_kv(new_cache["v"], new_cache.get("v_scale"), q.dtype)
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, kf,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    s = softcap(s, cfg.attn_softcap)
    tpos = jnp.arange(T)
    if kind == "attn_local":
        valid = (tpos[None] <= slot) | (pos >= T)   # rolled window full
    else:
        valid = tpos[None] <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", w.astype(vf.dtype), vf)
    o = o.reshape(B, 1, H, hd)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), new_cache


def quantize_kv(x, cache):
    """Per (B, T, K) head int8 quantization when the cache is int8."""
    if cache.get("k_scale") is None and cache["k"].dtype != jnp.int8:
        return x.astype(cache["k"].dtype), None
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(x, scale, dtype):
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) * scale).astype(dtype)
    return x.astype(dtype)


def init_kv_cache(cfg, kind: str, B: int, T: int, dtype):
    """T already window-clamped by the caller for local kinds."""
    K, hd = cfg.n_kv_heads, cfg.hd
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros((B, T, K, hd), jnp.int8),
                "v": jnp.zeros((B, T, K, hd), jnp.int8),
                "k_scale": jnp.zeros((B, T, K, 1), jnp.float32),
                "v_scale": jnp.zeros((B, T, K, 1), jnp.float32)}
    return {"k": jnp.zeros((B, T, K, hd), dtype),
            "v": jnp.zeros((B, T, K, hd), dtype)}


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_init(rng, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    ks = jax.random.split(rng, 6)
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "qn": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H, qk),
                           in_axis_size=m.q_lora_rank, dtype=dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim),
                            dtype=dtype),
        "kvn": jnp.zeros((m.kv_lora_rank,), dtype),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_dim),
                           in_axis_size=m.kv_lora_rank, dtype=dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                           in_axis_size=m.kv_lora_rank, dtype=dtype),
        "wo": dense_init(ks[5], (H, m.v_head_dim, d),
                         in_axis_size=H * m.v_head_dim, dtype=dtype),
    }


def _mla_qkv_latent(x, p, cfg, positions):
    m = cfg.mla
    q_lat = rmsnorm(x @ p["wq_a"], p["qn"])
    q = jnp.einsum("bsr,rhe->bshe", q_lat, p["wq_b"])
    q_nope = q[..., :m.qk_nope_dim]
    q_rope = apply_rope(q[..., m.qk_nope_dim:], positions, cfg.rope_theta)
    kv_a = x @ p["wkv_a"]
    ckv = rmsnorm(kv_a[..., :m.kv_lora_rank], p["kvn"])
    k_rope = apply_rope(kv_a[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)                   # [B,S,1,rope]
    return q_nope, q_rope, ckv, k_rope


def mla_apply(x, p, cfg, positions):
    """Full-sequence MLA: decompress per-head k/v from the latent (train path).
    Returns (out, (ckv, k_rope) latent for caching)."""
    m = cfg.mla
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(x, p, cfg, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["wk_b"])
    v = jnp.einsum("bsr,rhe->bshe", ckv, p["wv_b"])
    H = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, m.qk_rope_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = chunked_attention(q, k, v, chunk=cfg.attn_chunk)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), (ckv, k_rope[..., 0, :])


def mla_decode(x, p, cfg, cache, pos):
    """Absorbed-matrix MLA decode (DeepSeek-V3 §: weight absorption): scores
    against the latent cache directly — per-step cost independent of H·hd
    decompression. cache: {"ckv" [B,T,r], "krope" [B,T,rope]}."""
    m = cfg.mla
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    q_nope, q_rope, ckv_new, k_rope_new = _mla_qkv_latent(x, p, cfg, positions)
    new_cache = {
        "ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1),
        "krope": jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope_new[:, :, 0, :].astype(cache["krope"].dtype),
            pos, axis=1),
    }
    ckv = new_cache["ckv"].astype(x.dtype)              # [B,T,r]
    krope = new_cache["krope"].astype(x.dtype)          # [B,T,rope]
    # absorb W_k into q: q_eff [B,1,H,r]
    q_eff = jnp.einsum("bshe,rhe->bshr", q_nope, p["wk_b"])
    s = (jnp.einsum("bshr,btr->bhst", q_eff, ckv)
         + jnp.einsum("bshe,bte->bhst", q_rope, krope)).astype(jnp.float32)
    s = s / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    T = ckv.shape[1]
    s = jnp.where((jnp.arange(T) <= pos)[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", w.astype(ckv.dtype), ckv)
    o = jnp.einsum("bshr,rhe->bshe", ctx, p["wv_b"])    # [B,1,H,v]
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), new_cache


def init_mla_cache(cfg, B: int, T: int, dtype):
    m = cfg.mla
    return {"ckv": jnp.zeros((B, T, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((B, T, m.qk_rope_dim), dtype)}
