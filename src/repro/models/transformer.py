"""Decoder stack: layer-kind dispatch, scan-over-pattern stacking, remat.

The layer layout is (prefix, pattern × repeats, suffix) from the ArchConfig:
the repeated pattern is stacked on a leading axis and driven by lax.scan so
the HLO contains ONE copy of the pattern regardless of depth (compile time
and SPMD partitioning cost stay flat); irregular prefix/suffix layers unroll.
Each scan step is wrapped in jax.checkpoint (full remat: only layer-boundary
activations survive the forward pass).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ssm
from .layers import (apply_norm, dense_init, mlp_apply, mlp_init, norm_init,
                     sinusoidal_positions, softcap)
from .moe import moe_apply, moe_init

__all__ = ["init_params", "forward_full", "forward_decode", "init_cache",
           "loss_fn", "KIND_MIXER"]

KIND_MIXER = {
    "attn": "attn", "attn_local": "attn", "attn_global": "attn",
    "attn_moe": "attn", "mla_dense": "mla", "mla_moe": "mla",
    "rwkv": "rwkv", "rec": "rec",
}


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def init_block(rng, cfg, kind: str):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    mixer = KIND_MIXER[kind]
    p: dict[str, Any] = {"ln1": norm_init(cfg.norm, d, dt)}
    if mixer == "attn":
        p["mix"] = attn.attn_init(ks[0], cfg, dt)
    elif mixer == "mla":
        p["mix"] = attn.mla_init(ks[0], cfg, dt)
    elif mixer == "rwkv":
        p["mix"] = ssm.rwkv_init(ks[0], cfg, dt)
        p["ln2"] = norm_init(cfg.norm, d, dt)
        return p                      # rwkv carries its own channel mix
    elif mixer == "rec":
        p["mix"] = ssm.rglru_init(ks[0], cfg, dt)
    p["ln2"] = norm_init(cfg.norm, d, dt)
    if kind.endswith("_moe"):
        p["ffn"] = moe_init(ks[1], d, cfg.moe, dt)
    else:
        p["ffn"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp, dt)
    if cfg.post_norm:
        p["pn1"] = norm_init(cfg.norm, d, dt)
        p["pn2"] = norm_init(cfg.norm, d, dt)
    return p


def apply_block(p, x, cfg, kind: str, *, positions=None, cache=None, pos=None,
                constrain=None):
    """mode is implied: cache None => full-sequence; else one-token decode.
    Returns (x, new_cache_or_state, aux_loss)."""
    mixer = KIND_MIXER[kind]
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, x, p["ln1"])
    if mixer == "attn":
        if cache is None:
            o, kv = attn.attn_apply(h, p["mix"], cfg, kind, positions)
            new_cache = kv
        else:
            o, new_cache = attn.attn_decode(h, p["mix"], cfg, kind, cache, pos)
    elif mixer == "mla":
        if cache is None:
            o, new_cache = attn.mla_apply(h, p["mix"], cfg, positions)
        else:
            o, new_cache = attn.mla_decode(h, p["mix"], cfg, cache, pos)
    elif mixer == "rec":
        if cache is None:
            o, new_cache = ssm.rglru_apply(h, p["mix"], cfg)
        else:
            o, new_cache = ssm.rglru_decode(h, p["mix"], cfg, cache)
    else:  # rwkv: time mix + channel mix (its own block structure)
        if cache is None:
            o, (x_tm, s_fin) = ssm.rwkv_time_mix(h, p["mix"], cfg)
            x = x + o
            h2 = apply_norm(cfg.norm, x, p["ln2"])
            o2, x_cm = ssm.rwkv_channel_mix(h2, p["mix"])
            new_cache = {"s": s_fin, "x_tm": x_tm.astype(jnp.float32),
                         "x_cm": x_cm.astype(jnp.float32)}
            return x + o2, new_cache, aux
        else:
            o, st = ssm.rwkv_decode(h, p["mix"], cfg, cache)
            x = x + o
            h2 = apply_norm(cfg.norm, x, p["ln2"])
            o2, x_cm = ssm.rwkv_channel_mix(
                h2, p["mix"], x_prev=cache["x_cm"].astype(h2.dtype))
            st["x_cm"] = x_cm.astype(jnp.float32)
            return x + o2, st, aux
    if cfg.post_norm:
        o = apply_norm(cfg.norm, o, p["pn1"])
    x = x + o
    h = apply_norm(cfg.norm, x, p["ln2"])
    if kind.endswith("_moe"):
        f, aux = moe_apply(h, p["ffn"], cfg.moe, constrain=constrain)
    else:
        f = mlp_apply(h, p["ffn"], cfg.mlp)
    if cfg.post_norm:
        f = apply_norm(cfg.norm, f, p["pn2"])
    return x + f, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init / forward
# ---------------------------------------------------------------------------

def init_params(rng, cfg):
    dt = _dtype(cfg)
    pre, pat, reps, suf = cfg.layer_kinds()
    n_static = len(pre) + len(suf)
    ks = jax.random.split(rng, 3 + n_static + len(pat))
    params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), dtype=dt),
        "unembed": dense_init(ks[1], (cfg.d_model, cfg.vocab), dtype=dt),
        "lnf": norm_init(cfg.norm, cfg.d_model, dt),
    }
    ki = 2
    params["prefix"] = []
    for kind in pre:
        params["prefix"].append(init_block(ks[ki], cfg, kind))
        ki += 1
    params["suffix"] = []
    for kind in suf:
        params["suffix"].append(init_block(ks[ki], cfg, kind))
        ki += 1
    # pattern params stacked over repeats (scan axis)
    pattern_params = []
    for j, kind in enumerate(pat):
        sub = jax.random.split(ks[ki + j], reps)
        pattern_params.append(
            jax.vmap(lambda r: init_block(r, cfg, kind))(sub))
    params["pattern"] = pattern_params
    return params


def _embed_inputs(params, cfg, batch):
    if cfg.embed_inputs:
        x = batch["embeddings"].astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    return x


def _positions(cfg, batch, B, S, offset=0):
    if cfg.rope == "mrope":
        if "positions" in batch:
            return batch["positions"]
        base = jnp.arange(S) + offset
        return jnp.broadcast_to(base, (B, 3, S))
    return jnp.broadcast_to(jnp.arange(S) + offset, (B, S))


def forward_full(params, cfg, batch, *, constrain=None, want_cache=False):
    """Returns (logits [B,S,V], caches, aux). Used by train and prefill."""
    pre, pat, reps, suf = cfg.layer_kinds()
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = _positions(cfg, batch, B, S)
    if cfg.rope == "sinusoidal":
        x = x + sinusoidal_positions(jnp.arange(S), cfg.d_model
                                     ).astype(x.dtype)[None]
    aux_total = jnp.zeros((), jnp.float32)
    caches = {"prefix": [], "pattern": None, "suffix": []}

    def run_block(p, x, kind):
        if constrain is not None:
            # layer-boundary residency: batch over dp, optionally S over
            # 'model' (sequence parallelism; norms/residuals stay local)
            x = constrain(x, ("tokens", "seq", None))
        return apply_block(p, x, cfg, kind, positions=positions,
                           constrain=constrain)

    for p, kind in zip(params["prefix"], pre):
        fn = jax.checkpoint(functools.partial(run_block, kind=kind))
        x, c, a = fn(p, x)
        aux_total += a
        caches["prefix"].append(c)

    def scan_step(carry, p_group):
        x, aux = carry
        def inner(x, p_group):
            cs = []
            a_sum = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(pat):
                x, c, a = run_block(p_group[j], x, kind)
                cs.append(c)
                a_sum += a
            return x, tuple(cs), a_sum
        x, cs, a = jax.checkpoint(inner)(x, p_group)
        return (x, aux + a), cs

    if reps > 0:
        (x, aux_total), pat_caches = jax.lax.scan(
            scan_step, (x, aux_total), tuple(params["pattern"]))
        caches["pattern"] = pat_caches

    for p, kind in zip(params["suffix"], suf):
        fn = jax.checkpoint(functools.partial(run_block, kind=kind))
        x, c, a = fn(p, x)
        aux_total += a
        caches["suffix"].append(c)

    x = apply_norm(cfg.norm, x, params["lnf"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits, (caches if want_cache else None), aux_total


def forward_decode(params, cfg, cache, batch, pos, *, constrain=None):
    """One-token step. batch: {"tokens" [B,1]} or {"embeddings" [B,1,d]}.
    cache mirrors init_cache(). Returns (logits [B,1,V], new_cache)."""
    pre, pat, reps, suf = cfg.layer_kinds()
    x = _embed_inputs(params, cfg, batch)
    if cfg.rope == "sinusoidal":
        x = x + sinusoidal_positions(pos[None], cfg.d_model).astype(x.dtype)[None]

    new_cache = {"prefix": [], "pattern": None, "suffix": []}
    for p, kind, c in zip(params["prefix"], pre, cache["prefix"]):
        x, c2, _ = apply_block(p, x, cfg, kind, cache=c, pos=pos,
                               constrain=constrain)
        new_cache["prefix"].append(c2)

    def scan_step(x, pc):
        p_group, c_group = pc
        cs = []
        for j, kind in enumerate(pat):
            x, c2, _ = apply_block(p_group[j], x, cfg, kind, cache=c_group[j],
                                   pos=pos, constrain=constrain)
            cs.append(c2)
        return x, tuple(cs)

    if reps > 0:
        x, pat_caches = jax.lax.scan(
            scan_step, x, (tuple(params["pattern"]), cache["pattern"]))
        new_cache["pattern"] = pat_caches

    for p, kind, c in zip(params["suffix"], suf, cache["suffix"]):
        x, c2, _ = apply_block(p, x, cfg, kind, cache=c, pos=pos,
                               constrain=constrain)
        new_cache["suffix"].append(c2)

    x = apply_norm(cfg.norm, x, params["lnf"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits, new_cache


def _cache_for_kind(cfg, kind, B, T, dt):
    mixer = KIND_MIXER[kind]
    if mixer == "attn":
        Tk = min(T, cfg.window) if kind == "attn_local" and cfg.window else T
        return attn.init_kv_cache(cfg, kind, B, Tk, dt)
    if mixer == "mla":
        return attn.init_mla_cache(cfg, B, T, dt)
    if mixer == "rwkv":
        return ssm.rwkv_init_state(cfg, B)
    return ssm.rglru_init_state(cfg, B)


def init_cache(cfg, B: int, T: int):
    """Decode cache sized for positions [0, T). Local windows clamp storage;
    recurrent kinds store constant-size state (long_500k feasibility)."""
    dt = _dtype(cfg)
    pre, pat, reps, suf = cfg.layer_kinds()
    cache = {
        "prefix": [_cache_for_kind(cfg, k, B, T, dt) for k in pre],
        "suffix": [_cache_for_kind(cfg, k, B, T, dt) for k in suf],
        "pattern": None,
    }
    if reps > 0:
        def rep(k):
            one = _cache_for_kind(cfg, k, B, T, dt)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one)
        cache["pattern"] = tuple(rep(k) for k in pat)
    return cache


def loss_fn(params, cfg, batch, *, constrain=None):
    """Next-token cross entropy (mean over predicted positions).

    Sharding note: the vocab axis of ``logits`` is model-sharded; we avoid
    ``take_along_axis`` over it (which would all-gather the full [B,S,V]
    logits) by contracting against an iota==label mask — logsumexp and the
    label-logit contraction both reduce the sharded axis locally + one small
    psum (measured in EXPERIMENTS.md §Perf, hillclimb #1).
    """
    logits, _, aux = forward_full(params, cfg, batch, constrain=constrain)
    labels = batch["labels"] if "labels" in batch else batch["tokens"]
    lg = logits[:, :-1].astype(jnp.float32)
    tgt = labels[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)                        # [B,S-1]
    vmask = jax.nn.one_hot(tgt, cfg.vocab, dtype=jnp.float32)  # fused w/ mult
    ll = jnp.sum(lg * vmask, axis=-1)
    loss = jnp.mean(lse - ll)
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}
