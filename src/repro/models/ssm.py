"""Recurrent token mixers: RWKV-6 "Finch" and RG-LRU (RecurrentGemma/Griffin).

RWKV-6: data-dependent per-channel decay w_t, token-shift lerp with a shared
LoRA, per-head wkv state S [dk, dv]. Training uses a chunked formulation:
within a chunk all pairwise (t, s) interactions are computed in parallel via
log-space decay ratios (all ratios <= 1, numerically safe); the state carries
across chunks through a lax.scan — O(S·C) memory, sequential only in S/C.

RG-LRU: h_t = a_t·h_{t-1} + sqrt(1-a_t^2)·(i_t ⊙ u_t) with a_t data-dependent
diagonal decay; training uses lax.associative_scan (parallel prefix) over the
(a, b) composition monoid — the TPU-native translation of the GPU linear-scan
kernel. Both expose single-step decode with constant-size state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["rwkv_init", "rwkv_time_mix", "rwkv_channel_mix", "rwkv_decode",
           "rwkv_init_state", "rglru_init", "rglru_apply", "rglru_decode",
           "rglru_init_state"]


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------

_MIXES = ("r", "k", "v", "g", "w")


def rwkv_init(rng, cfg, dtype):
    d = cfg.d_model
    dk = cfg.rec.head_dim
    H = d // dk
    f = cfg.d_ff
    ks = iter(jax.random.split(rng, 24))
    lora = 32
    p = {
        # token-shift mixing: base mus + shared-A LoRA (simplified from the
        # per-mix A of the reference impl; noted in DESIGN.md)
        "mu_x": jnp.zeros((d,), dtype),
        "mu": {m: jnp.zeros((d,), dtype) for m in _MIXES},
        "lora_a": dense_init(next(ks), (d, lora), dtype=dtype),
        "lora_b": {m: dense_init(next(ks), (lora, d), in_axis_size=lora,
                                 dtype=dtype) for m in _MIXES},
        "wr": dense_init(next(ks), (d, d), dtype=dtype),
        "wk": dense_init(next(ks), (d, d), dtype=dtype),
        "wv": dense_init(next(ks), (d, d), dtype=dtype),
        "wg": dense_init(next(ks), (d, d), dtype=dtype),
        # decay: w_t = exp(-exp(w0 + tanh(x_w A_w) B_w))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wa": dense_init(next(ks), (d, 64), dtype=dtype),
        "wb": dense_init(next(ks), (64, d), in_axis_size=64, dtype=dtype),
        "u": jnp.zeros((H, dk), jnp.float32),           # current-token bonus
        "ln_w": jnp.ones((d,), dtype), "ln_b": jnp.zeros((d,), dtype),
        "wo": dense_init(next(ks), (d, d), dtype=dtype),
        # channel mix
        "cm_mu_k": jnp.zeros((d,), dtype), "cm_mu_r": jnp.zeros((d,), dtype),
        "cm_wk": dense_init(next(ks), (d, f), dtype=dtype),
        "cm_wv": dense_init(next(ks), (f, d), in_axis_size=f, dtype=dtype),
        "cm_wr": dense_init(next(ks), (d, d), dtype=dtype),
    }
    return p


def _shift(x, x_prev=None):
    """[B,S,d] -> previous token (zeros / carried state at t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if x_prev is None else x_prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _token_shift(x, xs, p):
    delta = xs - x
    xxx = x + delta * p["mu_x"]
    a = jnp.tanh(xxx @ p["lora_a"])
    return {m: x + delta * (p["mu"][m] + a @ p["lora_b"][m]) for m in _MIXES}


def _decay(xw, p):
    wlog = -jnp.exp(p["w0"] + jnp.tanh(xw.astype(jnp.float32) @
                                       p["wa"].astype(jnp.float32))
                    @ p["wb"].astype(jnp.float32))      # log w_t  (<= 0)
    return wlog


def _group_norm(x, w, b, H, eps=1e-5):
    """Per-head LayerNorm of the wkv output ([..., H, dk] flattened to d)."""
    shp = x.shape
    xg = x.reshape(shp[:-1] + (H, shp[-1] // H)).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = xg.var(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(shp) * w.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)


def _wkv_chunk(r, k, v, wlog, u, s0):
    """One chunk of the wkv recurrence (all f32).
    r,k,v: [B,C,H,dk]; wlog: [B,C,H,dk] (log decay, <=0); u: [H,dk];
    s0: [B,H,dk,dv]. Returns (out [B,C,H,dv], s1)."""
    B, C, H, dk = r.shape
    lp = jnp.cumsum(wlog, axis=1)                       # log w_1..t (incl.)
    lpx = lp - wlog                                     # log w_1..t-1 (excl.)
    # carry-in: token i<=0 reaches output t through decay w_1..w_{t-1}
    rp = r * jnp.exp(lpx)
    o_carry = jnp.einsum("bchk,bhkv->bchv", rp, s0)
    # intra-chunk: token s reaches output t>s through decay w_{s+1}..w_{t-1}
    ratio = jnp.exp(lpx[:, :, None] - lp[:, None, :])   # [B,C,C,H,dk] (t,s)
    tri = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])[None, :, :, None,
                                                            None]
    scores = jnp.einsum("bthk,btshk,bshk->bths", r, jnp.where(tri, ratio, 0.0),
                        k)
    o_intra = jnp.einsum("bths,bshv->bthv", scores, v)
    o_diag = jnp.einsum("bthk,hk,bthk->bth", r, u, k)[..., None] * v
    # state update: S1 = diag(P_C) S0 + sum_s (k_s ⊙ P_C/P_s)^T v_s
    pc = jnp.exp(lp[:, -1])                             # [B,H,dk]
    kfac = k * jnp.exp(lp[:, -1][:, None] - lp)         # k_s ⊙ P_C / P_s
    s1 = pc[..., None] * s0 + jnp.einsum("bshk,bshv->bhkv", kfac, v)
    return o_carry + o_intra + o_diag, s1


def rwkv_time_mix(x, p, cfg, x_prev=None, s0=None):
    """Full-sequence RWKV-6 time mix. Returns (out, (x_last, s_final))."""
    B, S, d = x.shape
    dk = cfg.rec.head_dim
    H = d // dk
    C = min(cfg.rec.chunk, S)
    assert S % C == 0, (S, C)
    mixed = _token_shift(x, _shift(x, x_prev), p)
    r = (mixed["r"] @ p["wr"]).reshape(B, S, H, dk).astype(jnp.float32)
    k = (mixed["k"] @ p["wk"]).reshape(B, S, H, dk).astype(jnp.float32)
    v = (mixed["v"] @ p["wv"]).reshape(B, S, H, dk).astype(jnp.float32)
    g = jax.nn.silu(mixed["g"] @ p["wg"])
    wlog = _decay(mixed["w"], p).reshape(B, S, H, dk)
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dk), jnp.float32)

    nchunk = S // C
    def step(s, args):
        rc, kc, vc, wc = args
        o, s = _wkv_chunk(rc, kc, vc, wc, p["u"], s)
        return s, o

    xs = [a.reshape(B, nchunk, C, H, dk).transpose(1, 0, 2, 3, 4)
          for a in (r, k, v, wlog)]
    s_fin, outs = jax.lax.scan(step, s0, tuple(xs))
    o = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, d)
    o = _group_norm(o.astype(x.dtype), p["ln_w"], p["ln_b"], H)
    out = (o * g) @ p["wo"]
    return out, (x[:, -1], s_fin)


def rwkv_channel_mix(x, p, x_prev=None):
    xs = _shift(x, x_prev)
    xk = x + (xs - x) * p["cm_mu_k"]
    xr = x + (xs - x) * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"]), x[:, -1]


def rwkv_init_state(cfg, B: int):
    d = cfg.d_model
    dk = cfg.rec.head_dim
    H = d // dk
    return {"s": jnp.zeros((B, H, dk, dk), jnp.float32),
            "x_tm": jnp.zeros((B, d), jnp.float32),
            "x_cm": jnp.zeros((B, d), jnp.float32)}


def rwkv_decode(x, p, cfg, state):
    """Single-token step. x [B,1,d]; state {"s","x_tm","x_cm"}; this covers
    BOTH time mix and channel mix (the block glue lives in transformer.py)."""
    B, _, d = x.shape
    dk = cfg.rec.head_dim
    H = d // dk
    xt = x[:, 0].astype(jnp.float32)
    mixed = _token_shift(x, state["x_tm"][:, None].astype(x.dtype), p)
    r = (mixed["r"] @ p["wr"]).reshape(B, H, dk).astype(jnp.float32)
    k = (mixed["k"] @ p["wk"]).reshape(B, H, dk).astype(jnp.float32)
    v = (mixed["v"] @ p["wv"]).reshape(B, H, dk).astype(jnp.float32)
    g = jax.nn.silu(mixed["g"] @ p["wg"])[:, 0]
    w = jnp.exp(_decay(mixed["w"], p)).reshape(B, H, dk)
    s = state["s"]
    # o_t = r·(u ⊙ (k ⊗ v) + S)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    o = jnp.einsum("bhk,bhkv->bhv", r, s + p["u"][None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    o = o.reshape(B, d)
    o = _group_norm(o.astype(x.dtype), p["ln_w"], p["ln_b"], H)
    out_tm = ((o * g) @ p["wo"])[:, None]
    return out_tm, {"s": s_new, "x_tm": xt, "x_cm": state["x_cm"]}


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin recurrent block)
# ---------------------------------------------------------------------------

def rglru_init(rng, cfg, dtype):
    d = cfg.d_model
    w = cfg.rec.lru_width or d
    cw = cfg.rec.conv_width
    ks = iter(jax.random.split(rng, 8))
    return {
        "wx": dense_init(next(ks), (d, w), dtype=dtype),    # recurrent branch
        "wy": dense_init(next(ks), (d, w), dtype=dtype),    # gate branch
        "conv_w": dense_init(next(ks), (cw, w), dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": dense_init(next(ks), (w, w), dtype=dtype),    # recurrence gate
        "ba": jnp.zeros((w,), jnp.float32),
        "wi": dense_init(next(ks), (w, w), dtype=dtype),    # input gate
        "bi": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 3.0, jnp.float32),            # Λ (softplus)
        "wo": dense_init(next(ks), (w, d), in_axis_size=w, dtype=dtype),
    }


_C_RGLRU = 8.0


def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv1d. u [B,S,w]; w [cw, w]; state [B, cw-1, w]."""
    cw = w.shape[0]
    pad = (jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
           if state is None else state.astype(u.dtype))
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(cw)) + b
    return out, up[:, -(cw - 1):]


def _rglru_gates(u, p):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -_C_RGLRU * r * jax.nn.softplus(p["lam"])    # [B,S,w] (<= 0)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def rglru_apply(x, p, cfg, state=None):
    """Full-sequence recurrent block. Returns (out, {"h", "conv"})."""
    u0 = x @ p["wx"]
    gate = jax.nn.gelu(x @ p["wy"], approximate=True)
    conv_state = None if state is None else state["conv"]
    u, conv_new = _causal_conv(u0, p["conv_w"], p["conv_b"], conv_state)
    a, b = _rglru_gates(u, p)
    if state is not None:
        # inject carried h0 through the first step: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * state["h"])

    def combine(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = ((h.astype(x.dtype) * gate) @ p["wo"])
    return out, {"h": h[:, -1], "conv": conv_new.astype(jnp.float32)}


def rglru_init_state(cfg, B: int):
    w = cfg.rec.lru_width or cfg.d_model
    return {"h": jnp.zeros((B, w), jnp.float32),
            "conv": jnp.zeros((B, cfg.rec.conv_width - 1, w), jnp.float32)}


def rglru_decode(x, p, cfg, state):
    """Single-step. x [B,1,d]."""
    u0 = x @ p["wx"]
    gate = jax.nn.gelu(x @ p["wy"], approximate=True)
    u, conv_new = _causal_conv(u0, p["conv_w"], p["conv_b"], state["conv"])
    a, b = _rglru_gates(u, p)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = ((h[:, None].astype(x.dtype) * gate) @ p["wo"])
    return out, {"h": h, "conv": conv_new.astype(jnp.float32)}
