"""LMModel: the public step API + sharding rules + input specs.

Sharding (see DESIGN.md §4): mesh axes ('pod','data','model') / ('data',
'model'); batch over the dp axes, heads/d_ff/vocab over 'model', MoE experts
over 'data' with expert d_ff over 'model'. Optimizer state inherits param
specs. The same module serves real execution (CPU smoke tests) and the
abstract multi-pod dry-run (everything below works on ShapeDtypeStructs).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..optim import (adafactor_init, adafactor_update, adamw_init,
                     adamw_update)
from . import transformer as tfm

__all__ = ["LMModel", "param_specs", "input_specs", "batch_specs",
           "cache_specs", "dp_axes"]


def dp_axes(mesh: Mesh, cfg: Optional[ArchConfig] = None):
    if cfg is not None and cfg.pure_dp:
        return tuple(mesh.axis_names)
    return tuple(a for a in mesh.axis_names if a != "model")


def _dp_or_none(mesh: Mesh, B: int, cfg: Optional[ArchConfig] = None):
    dp = dp_axes(mesh, cfg)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return dp if B % size == 0 else None


# ---------------------------------------------------------------------------
# Parameter sharding rules (path + shape pattern matched)
# ---------------------------------------------------------------------------

def _leaf_spec(names: list[str], leaf_ndim: int) -> P:
    name = names[-1]
    stacked = "pattern" in names            # scan axis prepended
    nd = leaf_ndim - (1 if stacked else 0)

    def out(*spec):
        assert len(spec) == nd, (names, leaf_ndim, spec)
        return P(*(((None,) if stacked else ()) + spec))

    moe_ctx = "ffn" in names and nd == 3    # stacked expert weights
    if name == "embed":
        return P("model", None)
    if name == "unembed":
        return P(None, "model")
    if name in ("wq", "wk", "wv") and nd == 3:
        return out(None, "model", None)
    if name == "wo" and nd == 3:
        return out("model", None, None)
    if name in ("bq", "bk", "bv") and nd == 2:
        return out("model", None)
    if name in ("wq_b", "wk_b", "wv_b"):
        return out(None, "model", None)
    if name in ("wg", "wu"):
        return out("data", None, "model") if moe_ctx else out(None, "model")
    if name == "wd":
        return out("data", "model", None) if moe_ctx else out("model", None)
    if name in ("wr", "wk", "wv", "wg", "cm_wk", "cm_wr", "wx", "wy",
                "wa", "wi") and nd == 2:
        return out(None, "model")
    if name in ("wo", "cm_wv") and nd == 2:
        return out("model", None)
    if name == "u" and nd == 2:             # rwkv bonus [H, dk]
        return out(None, None)
    # everything else (norms, biases, router, loras, conv, lambda): replicated
    return P(*([None] * leaf_ndim))


def _sanitize(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop sharding on dims the mesh axis size does not divide (e.g. 15 GQA
    heads over model=16 -> replicate; recorded as a hillclimb opportunity)."""
    if mesh is None:
        return spec
    out = []
    for i, s in enumerate(spec):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(s if shape[i] % size == 0 else None)
    return P(*out)


def param_specs(cfg: ArchConfig, abstract_params, mesh: Optional[Mesh] = None
                ) -> Any:
    def spec(path, leaf):
        if cfg.pure_dp:   # small models: replicate weights, batch everywhere
            return P(*([None] * leaf.ndim))
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        return _sanitize(_leaf_spec(names, leaf.ndim), leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(spec, abstract_params)


def zero1_specs(cfg: ArchConfig, pspecs, abstract, mesh: Mesh):
    """ZeRO-1: additionally shard a replicated-or-spare dim over 'data'
    (over ALL axes under pure_dp). Applied to the grad accumulator and
    optimizer state (not params)."""
    zaxes = tuple(mesh.axis_names) if cfg.pure_dp else ("data",)
    dsize = 1
    for a in zaxes:
        dsize *= mesh.shape[a]

    def used(s):
        return "data" in ((s,) if not isinstance(s, tuple) else s) \
            if s is not None else False

    def upd(ps, leaf):
        spec = list(tuple(ps)) + [None] * (leaf.ndim - len(tuple(ps)))
        if any(used(s) for s in spec):
            return P(*spec)          # expert weights already shard over data
        for i, s in enumerate(spec):
            if s is None and leaf.shape[i] % dsize == 0 and \
                    leaf.shape[i] >= dsize:
                spec[i] = zaxes if len(zaxes) > 1 else zaxes[0]
                break
        return P(*spec)

    return jax.tree.map(upd, pspecs, abstract,
                        is_leaf=lambda x: isinstance(x, P))


def _state_specs(cfg: ArchConfig, pspecs, abstract_state):
    """Optimizer state: m/v (or vr/vc) inherit param specs, truncated to the
    factored shapes for adafactor; scalars replicated."""
    if cfg.optimizer == "adafactor":
        def vr_spec(ps, leaf):
            sp = tuple(ps) if isinstance(ps, P) else (ps,)
            return P(*sp[:leaf.ndim]) if leaf.ndim else P()
        # align by tree structure: state.vr / state.vc mirror params
        vr = jax.tree.map(lambda ps, l: P(*tuple(ps)[:l.ndim]),
                          pspecs, abstract_state.vr,
                          is_leaf=lambda x: isinstance(x, P))
        vc = jax.tree.map(
            lambda ps, l: P(*(tuple(ps)[:l.ndim - 1] + tuple(ps)[-1:]))
            if l.ndim > 1 else P(*([None] * l.ndim)),
            pspecs, abstract_state.vc, is_leaf=lambda x: isinstance(x, P))
        return type(abstract_state)(step=P(), vr=vr, vc=vc)
    return type(abstract_state)(step=P(), m=pspecs, v=pspecs)


# ---------------------------------------------------------------------------
# Batch / cache specs (ShapeDtypeStruct factories for the dry-run)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, mesh: Mesh, B: int, S: int, *, decode=False):
    """Returns (pytree of ShapeDtypeStruct, pytree of PartitionSpec)."""
    dp = _dp_or_none(mesh, B, cfg)
    dt = jnp.dtype(cfg.dtype)
    shapes, specs = {}, {}
    if cfg.embed_inputs:
        shapes["embeddings"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        specs["embeddings"] = P(dp, None, None)
        if not decode:
            shapes["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            specs["labels"] = P(dp, None)
    else:
        shapes["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["tokens"] = P(dp, None)
    if cfg.rope == "mrope":
        shapes["positions"] = jax.ShapeDtypeStruct((B, 3, S), jnp.int32)
        specs["positions"] = P(dp, None, None)
    return shapes, specs


def cache_specs(cfg: ArchConfig, mesh: Mesh, B: int, T: int):
    dp = _dp_or_none(mesh, B, cfg)
    abstract = jax.eval_shape(lambda: tfm.init_cache(cfg, B, T))

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        stacked = "pattern" in names
        name = names[-1]
        base: tuple
        if name in ("k", "v", "k_scale", "v_scale"):
            if cfg.shard_cache_t:
                base = (dp, "model", None, None)
            else:
                base = (dp, None, "model", None)
        elif name in ("ckv", "krope"):
            base = (dp, "model", None) if cfg.shard_cache_t \
                else (dp, None, None)
        elif name == "s":                    # rwkv state [B,H,dk,dv]
            base = (dp, "model", None, None)
        elif name in ("x_tm", "x_cm"):
            base = (dp, None)
        elif name == "h":
            base = (dp, "model")
        elif name == "conv":
            base = (dp, None, "model")
        else:
            base = tuple([None] * leaf.ndim)
        base = base[:leaf.ndim - (1 if stacked else 0)]
        full = P(*(((None,) if stacked else ()) + base))
        return _sanitize(full, leaf.shape, mesh)

    return abstract, jax.tree_util.tree_map_with_path(spec, abstract)


def input_specs(cfg: ArchConfig, shape, mesh: Mesh):
    """ShapeDtypeStruct stand-ins + PartitionSpecs for one (arch, shape) cell.

    train:   (batch,)
    prefill: (batch,)
    decode:  (cache, batch, pos)  — one new token against a T=seq_len cache
    """
    if shape.kind == "train":
        b, s = batch_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        return {"batch": b}, {"batch": s}
    if shape.kind == "prefill":
        b, s = batch_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        return {"batch": b}, {"batch": s}
    # decode
    b, bs = batch_specs(cfg, mesh, shape.global_batch, 1, decode=True)
    cache, cs = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ({"cache": cache, "batch": b, "pos": pos},
            {"cache": cs, "batch": bs, "pos": P()})


# ---------------------------------------------------------------------------
# Model wrapper
# ---------------------------------------------------------------------------

class LMModel:
    """Step functions for one architecture, mesh-aware."""

    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh

    # ---- params ----------------------------------------------------------
    def init_params(self, rng):
        return tfm.init_params(rng, self.cfg)

    def abstract_params(self):
        return jax.eval_shape(
            lambda: tfm.init_params(jax.random.key(0), self.cfg))

    def param_partition(self):
        return param_specs(self.cfg, self.abstract_params(), self.mesh)

    def _constrain(self):
        mesh = self.mesh
        if mesh is None:
            return None
        dp = dp_axes(mesh, self.cfg)
        seq = "model" if (self.cfg.seq_parallel
                          and not self.cfg.pure_dp) else None

        def cst(t, axes):
            spec = []
            for i, a in enumerate(axes):
                if a == "tokens":
                    spec.append(dp)
                elif a == "expert":
                    spec.append(None if self.cfg.pure_dp else "data")
                elif a == "seq":
                    spec.append(seq if t.shape[i] % mesh.shape["model"] == 0
                                else None)
                else:
                    spec.append(a)
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, P(*spec)))
        return cst

    # ---- optimizer -------------------------------------------------------
    def init_opt(self, params):
        if self.cfg.optimizer == "adafactor":
            return adafactor_init(params)
        return adamw_init(params)

    def opt_partition(self, pspecs):
        abstract = jax.eval_shape(self.init_opt, self.abstract_params())
        if self.cfg.zero1 and self.mesh is not None:
            pspecs = zero1_specs(self.cfg, pspecs,
                                 self.abstract_params(), self.mesh)
        return _state_specs(self.cfg, pspecs, abstract)

    # ---- steps -----------------------------------------------------------
    def loss(self, params, batch):
        return tfm.loss_fn(params, self.cfg, batch,
                           constrain=self._constrain())

    def train_step(self, params, opt_state, batch):
        """Grad accumulation over microbatches (lax.scan), then one update."""
        cfg = self.cfg
        bkey = "embeddings" if cfg.embed_inputs else "tokens"
        B = batch[bkey].shape[0]
        mb = min(cfg.microbatch, B)
        n_micro = B // mb
        acc_dt = jnp.dtype(cfg.grad_accum_dtype)

        def reshape(x):
            return x.reshape((n_micro, mb) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def gfn(p, b):
            (l, metrics), g = jax.value_and_grad(self.loss, has_aux=True)(p, b)
            return g, metrics

        def step(acc, mb_batch):
            g, metrics = gfn(params, mb_batch)
            acc = jax.tree.map(lambda a, gi: a + gi.astype(acc_dt), acc, g)
            return acc, metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        if cfg.zero1 and self.mesh is not None:
            gspecs = zero1_specs(cfg, self.param_partition(),
                                 self.abstract_params(), self.mesh)
            gshard = jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), gspecs,
                is_leaf=lambda x: isinstance(x, P))
            zeros = jax.tree.map(jax.lax.with_sharding_constraint, zeros,
                                 gshard)
        grads, metrics = jax.lax.scan(step, zeros, micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        if cfg.optimizer == "adafactor":
            new_params, new_state, gn = adafactor_update(grads, opt_state,
                                                         params)
        else:
            new_params, new_state, gn = adamw_update(grads, opt_state, params)
        out_metrics = {"loss": jnp.mean(metrics["loss"]),
                       "aux": jnp.mean(metrics["aux"]), "grad_norm": gn}
        return new_params, new_state, out_metrics

    def prefill_step(self, params, batch):
        logits, caches, _ = tfm.forward_full(params, self.cfg, batch,
                                             constrain=self._constrain(),
                                             want_cache=True)
        return logits[:, -1], caches

    def decode_step(self, params, cache, batch, pos):
        return tfm.forward_decode(params, self.cfg, cache, batch, pos,
                                  constrain=self._constrain())
