"""Shared neural layers: norms, MLPs, rotary embeddings, initializers.

Conventions:
  * params are nested dicts of jnp arrays; compute dtype = activations dtype
    (bf16 by default), norm/softmax statistics in f32.
  * weight layouts are chosen so the model-parallel axis is always the one
    named dimension sharded over 'model' (see model.py sharding rules).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["dense_init", "rmsnorm", "layernorm", "norm_init", "apply_norm",
           "mlp_init", "mlp_apply", "rope_freqs", "apply_rope",
           "mrope_apply", "sinusoidal_positions", "softcap"]


def dense_init(rng, shape, in_axis_size=None, dtype=jnp.bfloat16):
    """Truncated-normal fan-in init."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_init(kind: str, d: int, dtype=jnp.bfloat16):
    if kind == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.zeros((d,), dtype)}  # rmsnorm stores (scale - 1)


def apply_norm(kind: str, x, p):
    if kind == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, f: int, kind: str, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 3)
    if kind in ("swiglu", "geglu"):
        return {"wg": dense_init(ks[0], (d, f), dtype=dtype),
                "wu": dense_init(ks[1], (d, f), dtype=dtype),
                "wd": dense_init(ks[2], (f, d), in_axis_size=f, dtype=dtype)}
    return {"wu": dense_init(ks[0], (d, f), dtype=dtype),
            "wd": dense_init(ks[1], (f, d), in_axis_size=f, dtype=dtype)}


def mlp_apply(x, p, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wu"])
    else:  # gelu
        h = jax.nn.gelu(x @ p["wu"], approximate=True)
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    """Inverse frequencies [hd//2] (f32)."""
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(x, positions, theta: float):
    """x [..., S, H, hd]; positions broadcastable to [..., S] (int)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv      # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x, cos, sin)


def mrope_apply(x, positions3, theta: float, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: the hd/2 freq channels split into (t, h, w) groups,
    each rotated by its own position stream. positions3: [B, 3, S]."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, theta)                               # [hd/2]
    pos = positions3.astype(jnp.float32)                      # [B,3,S]
    ang_all = pos[..., None] * inv                            # [B,3,S,hd/2]
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[:, i, :, off:off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)                     # [B,S,hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x, cos, sin)


def sinusoidal_positions(positions, d: int):
    """Classic transformer sinusoidal table for given positions [...]."""
    half = d // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
