from .model import LMModel, input_specs, param_specs
__all__ = ["LMModel", "input_specs", "param_specs"]
