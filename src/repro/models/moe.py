"""Mixture-of-Experts with capacity-bounded, sort-free dispatch.

Dispatch is "token-choice with per-expert top-C": the router produces a
[N, E] gate matrix (top-k per token); each expert then takes its top-C tokens
by gate — two top-k ops, no giant [N, E, C] one-hot, no unbounded sort. This
mirrors the paper's degree-partition philosophy: regular, capacity-padded
compute for the bulk, explicit drop handling for the tail (DESIGN.md §5).

Expert parallelism: experts are sharded over 'data' and expert d_ff over
'model'. Under jit+NamedSharding the dispatch gather / combine scatter are
expressed with sharding constraints so GSPMD emits the EP collective
pattern. DS-V3 refinements (both exercised by the --opt dry-run variant and
covered by smoke tests): node-limited *group routing* (tokens restricted to
`group_top` of `n_groups` expert groups — cuts a2a locality cost) and
low-precision *fp8 dispatch* (the dispatch leg of the a2a carries
float8_e4m3; expert compute upcasts after the constraint).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "capacity"]


def capacity(n_tokens: int, cfg_moe) -> int:
    c = int(math.ceil(n_tokens * cfg_moe.top_k * cfg_moe.capacity_factor
                      / cfg_moe.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_init(rng, d: int, moe, dtype):
    E, F = moe.n_experts, moe.d_ff_expert
    ks = jax.random.split(rng, 5)
    p = {"router": dense_init(ks[0], (d, E), dtype=jnp.float32),
         "wg": dense_init(ks[1], (E, d, F), in_axis_size=d, dtype=dtype),
         "wu": dense_init(ks[2], (E, d, F), in_axis_size=d, dtype=dtype),
         "wd": dense_init(ks[3], (E, F, d), in_axis_size=F, dtype=dtype)}
    if moe.n_shared:
        from .layers import mlp_init
        p["shared"] = mlp_init(ks[4], d, F * moe.n_shared, "swiglu",
                               dtype=dtype)
    return p


def _route(x_flat, p, moe):
    """Returns dense gate matrix [N, E] (f32, zeros off the top-k) + aux loss."""
    logits = (x_flat.astype(jnp.float32) @ p["router"])          # [N, E]
    if moe.router == "sigmoid":                                  # DeepSeek-V3
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    if moe.n_groups and moe.group_top:
        # DS-V3 node-limited routing: score each expert group by the sum of
        # its top-2 affinities, keep only the top `group_top` groups
        N, E = scores.shape
        g = scores.reshape(N, moe.n_groups, E // moe.n_groups)
        gscore = jnp.sum(jax.lax.top_k(g, min(2, g.shape[-1]))[0], axis=-1)
        _, gidx = jax.lax.top_k(gscore, moe.group_top)
        gmask = jnp.zeros_like(gscore).at[
            jnp.arange(N)[:, None], gidx].set(1.0)
        scores = (g * gmask[..., None]).reshape(N, E)
    top_vals, top_idx = jax.lax.top_k(scores, moe.top_k)
    top_vals = top_vals / jnp.maximum(jnp.sum(top_vals, -1, keepdims=True),
                                      1e-9)
    gates = jnp.zeros_like(scores).at[
        jnp.arange(scores.shape[0])[:, None], top_idx].set(top_vals)
    # Switch-style load-balance aux loss
    E = scores.shape[-1]
    me = jnp.mean(gates > 0, axis=0)          # fraction routed per expert
    pe = jnp.mean(scores, axis=0)             # mean router prob per expert
    aux = E * jnp.sum(me * pe)
    return gates, aux


def _expert_ffn(xe, p):
    """xe [E, C, d] -> [E, C, d] batched SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    return jnp.einsum("ecf,efd->ecd", h, p["wd"])


def moe_apply(x, p, moe, *, constrain=None):
    """x [B, S, d] -> [B, S, d]. `constrain(tensor, logical_axes)` applies
    sharding constraints (injected by model.py; identity when None)."""
    B, S, d = x.shape
    cst = constrain or (lambda t, ax: t)
    x_flat = x.reshape(B * S, d)
    N = B * S
    gates, aux = _route(x_flat, p, moe)                          # [N, E]
    C = min(capacity(N, moe), N)   # decode: a single token caps capacity
    # per-expert top-C tokens (ties to zero-gate tokens contribute 0)
    vals, idx = jax.lax.top_k(gates.T, C)                        # [E, C]
    xe = jnp.take(x_flat, idx, axis=0)                           # [E, C, d]
    if moe.dispatch_dtype != "bfloat16":
        # DS-V3-style low-precision dispatch: the EP all-to-all carries fp8;
        # expert compute runs in the model dtype after the constraint
        xe = xe.astype(jnp.dtype(moe.dispatch_dtype))
    xe = cst(xe, ("expert", None, None))
    xe = xe.astype(x.dtype)
    ye = _expert_ffn(xe, p)                                      # [E, C, d]
    ye = cst(ye, ("expert", None, None))
    ye = ye * vals[..., None].astype(ye.dtype)
    out = jnp.zeros((N, d), ye.dtype).at[idx.reshape(-1)].add(
        ye.reshape(-1, d))
    out = cst(out, ("tokens", None))
    if "shared" in p:
        from .layers import mlp_apply
        out = out + mlp_apply(x_flat, p["shared"], "swiglu")
    return out.reshape(B, S, d), aux
