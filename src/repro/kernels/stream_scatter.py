"""Pallas TPU kernel: in-place row scatter for streaming snapshot updates.

A batch Δ^t touches O(|Δ|) rows of the [n, d_p] ELL index matrix (or tile
slots of the [t_cap, tile] pool); rebuilding or copying the whole array per
batch would reintroduce the O(|E|) cost the stream subsystem exists to
avoid. This kernel writes *only* the edited rows, with the destination
aliased to the source buffer (``input_output_aliases``) so the update is
genuinely in place — graph mutation as a first-class device operation.

Mechanics: grid = one program per edited row; row ids arrive via scalar
prefetch and drive the *output* index map (the Pallas idiom for a
data-dependent scatter). Rows not visited by any program keep the aliased
input contents. Duplicate row ids are permitted only when they carry
identical contents — the pad convention is "repeat entry 0", which
satisfies this by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..obs.spans import get_registry as _obs
from .common import default_interpret as _default_interpret

__all__ = ["scatter_rows", "ell_scatter_rows"]


def _copy_kernel(rows_ref, dst_ref, new_ref, out_ref):
    del rows_ref, dst_ref  # rows feed the index map; dst is only aliased
    out_ref[...] = new_ref[...]


def scatter_rows(dst: jnp.ndarray, rows: jnp.ndarray, new_rows: jnp.ndarray,
                 *, interpret: bool | None = None) -> jnp.ndarray:
    """out = dst with out[rows[i]] = new_rows[i]; dst's buffer is reused.

    dst: [n, d] ; rows: [K] int32 (pad by repeating rows[0]) ; new_rows: [K, d].
    """
    interpret = _default_interpret() if interpret is None else interpret
    k, d = new_rows.shape
    # trace-time only (the call site is jitted): counts kernel *builds*, and
    # rows are counted per build — re-executions of the cached computation
    # are invisible to host counters by design.
    _obs().inc("kernels.stream_scatter.calls")
    _obs().inc("kernels.stream_scatter.rows", k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),        # aliased, never read
            pl.BlockSpec((1, d), lambda i, rows: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, rows: (rows[i], 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={1: 0},   # dst (after the prefetch arg) -> out
        interpret=interpret,
    )(rows, dst, new_rows)


def ell_scatter_rows(ell_idx: jnp.ndarray, ell_mask: jnp.ndarray,
                     rows: jnp.ndarray, new_idx: jnp.ndarray,
                     new_mask: jnp.ndarray, *, interpret: bool | None = None):
    """Scatter edited (index, mask) row pairs of an ELL layout in place."""
    return (scatter_rows(ell_idx, rows, new_idx, interpret=interpret),
            scatter_rows(ell_mask, rows, new_mask, interpret=interpret))
