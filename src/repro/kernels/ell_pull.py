"""Pallas TPU kernel: lane-per-vertex ELL pull (paper's thread-per-vertex kernel).

Low in-degree vertices are packed into a dense padded index matrix
``ell_idx [n, d_p]``; each kernel instance owns a tile of ``vt`` vertices and
computes a masked gather + row-sum with the contribution vector ``c`` held
resident in VMEM (valid for |V| up to ~2M at f32 — above that, use the
gather-outside path in ``pr_update``; see DESIGN.md §2 "gather locality").

The VPU sees fully regular work: ``vt`` rows × ``d_p`` lanes, no divergence —
the TPU translation of the paper's low-degree kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import resolve_interpret

__all__ = ["ell_pull"]


def _kernel(c_ref, idx_ref, mask_ref, out_ref):
    c = c_ref[...]
    idx = idx_ref[...]
    mask = mask_ref[...]
    gathered = jnp.take(c, idx, axis=0)          # [vt, d_p] vector gather
    out_ref[...] = jnp.sum(gathered * mask.astype(c.dtype), axis=1)


def ell_pull(c: jnp.ndarray, ell_idx: jnp.ndarray, ell_mask: jnp.ndarray,
             *, vt: int = 512, interpret: bool | None = None) -> jnp.ndarray:
    """out[v] = sum_j c[ell_idx[v, j]] * ell_mask[v, j].

    c: [n] f32/f64 ; ell_idx/ell_mask: [nv, d_p]. nv is padded to vt.
    """
    interpret = resolve_interpret(interpret)
    nv, d_p = ell_idx.shape
    pad = (-nv) % vt
    if pad:
        ell_idx = jnp.pad(ell_idx, ((0, pad), (0, 0)))
        ell_mask = jnp.pad(ell_mask, ((0, pad), (0, 0)))
    npad = nv + pad
    grid = (npad // vt,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(c.shape, lambda i: (0,)),            # c resident
            pl.BlockSpec((vt, d_p), lambda i: (i, 0)),
            pl.BlockSpec((vt, d_p), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((vt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), c.dtype),
        interpret=interpret,
    )(c, ell_idx, ell_mask)
    return out[:nv]
