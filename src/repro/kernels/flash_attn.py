"""Pallas TPU kernel: causal flash attention (online-softmax blocking).

The LM substrate's chunked-attention schedule (models/attention.py) is the
jnp expression of this kernel; this is the Mosaic-tiled version for real TPU
deployment, validated in interpret mode against ref.py's exact softmax.

Grid: (BH, nq, nk) with the kv axis innermost (sequential on TPU). Running
max / denominator / accumulator live in VMEM scratch across kv steps; the
output block is written once on the last kv step (one write per q tile —
the same discipline as the PageRank kernels). Causal masking prunes nothing
structurally (full rectangle, masked), matching the jnp schedule so the
roofline accounting stays consistent; block sizes default to MXU-friendly
(128, 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import resolve_interpret

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

__all__ = ["flash_attention"]

NEG = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, scale, bq, bk,
            nk, causal):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)                  # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    bq: int = 128, bk: int = 128, causal: bool = True,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q [BH, S, D], k/v [BH, T, D] (GQA: repeat kv heads before the call).
    Returns [BH, S, D]."""
    interpret = resolve_interpret(interpret)
    BH, S, D = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_kernel, scale=scale, bq=bq, bk=bk, nk=nk,
                             causal=causal)
    scratch = ([_VMEM((bq, 1), jnp.float32), _VMEM((bq, 1), jnp.float32),
                _VMEM((bq, D), jnp.float32)] if _VMEM is not None else
               [pl.ANY] * 3)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
