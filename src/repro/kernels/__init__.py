"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships with a pure-jnp oracle in ref.py; tests sweep shapes and
dtypes in interpret mode (this container is CPU-only; TPU is the target).
"""
from .common import default_interpret
from .ell_pull import ell_pull
from .ell_bucket_pull import ell_bucket_pull, fused_ell_update
from .csr_block import csr_block_pull
from .pr_update import pr_update
from .linf_delta import linf_delta
from .flash_attn import flash_attention
from .ops import pull_sum_kernels, update_ranks_kernel
from .stream_scatter import scatter_rows, ell_scatter_rows

__all__ = ["ell_pull", "ell_bucket_pull", "fused_ell_update",
           "csr_block_pull", "pr_update", "linf_delta",
           "pull_sum_kernels", "update_ranks_kernel", "default_interpret",
           "flash_attention", "scatter_rows", "ell_scatter_rows"]
