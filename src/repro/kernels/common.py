"""Shared kernel-launch policy.

One home for the interpret-mode default so every kernel module can import
it without cycling through `ops` (which imports the kernel modules): on
TPU the kernels compile via Mosaic; everywhere else (this container is
CPU-only) they run in Pallas interpret mode. Callers can still force
either mode per call with ``interpret=True/False``; ``None`` means "ask
the backend".
"""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else interpret
