"""Pallas TPU kernel: tiled-CSR pull for high in-degree vertices
(the paper's block-per-vertex kernel with shared-memory reduction).

Each high-degree vertex's in-edge list is padded to whole tiles of ``tile``
edges (host-side, graph.py). The kernel walks the sequential TPU grid over
tiles; a scalar-prefetched tile→row map (SMEM) tells each step which output
slot to accumulate into — the VMEM-resident output block plays the role of
the CUDA shared-memory accumulator, and grid sequentiality replaces the block
reduction (no atomics, exactly one read-modify-write per tile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import resolve_interpret

__all__ = ["csr_block_pull"]


def _kernel(rowmap_ref, c_ref, tiles_ref, tmask_ref, out_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = c_ref[...]
    idx = tiles_ref[0]                           # [tile]
    mask = tmask_ref[0].astype(c.dtype)
    s = jnp.sum(jnp.take(c, idx, axis=0) * mask)
    row = rowmap_ref[t]
    out_ref[pl.ds(row, 1)] = out_ref[pl.ds(row, 1)] + s


def csr_block_pull(c: jnp.ndarray, hi_tiles: jnp.ndarray,
                   hi_tmask: jnp.ndarray, hi_rowmap: jnp.ndarray,
                   n_rows: int, *, tile_sel: jnp.ndarray | None = None,
                   interpret: bool | None = None) -> jnp.ndarray:
    """out[hi_rowmap[t]] += sum(c[hi_tiles[t]] * hi_tmask[t]) for each tile t.

    Returns per-high-slot sums, shape [n_rows]. With `tile_sel` (a compacted
    [k_t] active-tile list, sentinel == t_cap — core.frontier.ActiveFrontier)
    the grid iterates over the k_t selected tiles only: the tile tables are
    pre-gathered at `tile_sel` (dead lanes read mask 0 and accumulate 0 into
    the pad slot) so per-call edge work is O(k_t · tile), not O(t_cap · tile).
    Only exact when the selection covers every live tile of the rows the
    caller reads (overflow ⇒ use the full walk).
    """
    interpret = resolve_interpret(interpret)
    if tile_sel is not None:
        hi_tiles = jnp.take(hi_tiles, tile_sel, axis=0, mode="fill",
                            fill_value=0)
        hi_tmask = jnp.take(hi_tmask, tile_sel, axis=0, mode="fill",
                            fill_value=0.0)
        hi_rowmap = jnp.take(hi_rowmap, tile_sel, mode="fill",
                             fill_value=n_rows - 1)
    t_cap, tile = hi_tiles.shape
    grid = (t_cap,)
    try:
        from jax.experimental.pallas import tpu as pltpu
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(c.shape, lambda t, rm: (0,)),
                pl.BlockSpec((1, tile), lambda t, rm: (t, 0)),
                pl.BlockSpec((1, tile), lambda t, rm: (t, 0)),
            ],
            out_specs=pl.BlockSpec((n_rows,), lambda t, rm: (0,)),
        )
        return pl.pallas_call(
            _kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((n_rows,), c.dtype),
            interpret=interpret,
        )(hi_rowmap, c, hi_tiles, hi_tmask)
    except (ImportError, AttributeError):
        # Fallback spelling for pallas versions without PrefetchScalarGridSpec
        def _kernel2(rowmap_ref, c_ref, tiles_ref, tmask_ref, out_ref):
            _kernel(rowmap_ref, c_ref, tiles_ref, tmask_ref, out_ref)

        return pl.pallas_call(
            _kernel2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(hi_rowmap.shape, lambda t: (0,)),
                pl.BlockSpec(c.shape, lambda t: (0,)),
                pl.BlockSpec((1, tile), lambda t: (t, 0)),
                pl.BlockSpec((1, tile), lambda t: (t, 0)),
            ],
            out_specs=pl.BlockSpec((n_rows,), lambda t: (0,)),
            out_shape=jax.ShapeDtypeStruct((n_rows,), c.dtype),
            interpret=interpret,
        )(hi_rowmap, c, hi_tiles, hi_tmask)
