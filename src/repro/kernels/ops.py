"""Jit'd wrappers binding the Pallas kernels to the core engine.

``pull_sum_kernels(dg, c)`` is a drop-in ``pull_sum_fn`` for
``core.pagerank``/``core.dynamic``: ELL side via the lane-per-vertex kernel,
high-degree side via the tiled-CSR kernel. ``interpret`` defaults to True on
CPU (this container) and False on TPU, where the kernels compile via Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .csr_block import csr_block_pull
from .ell_pull import ell_pull
from .linf_delta import linf_delta
from .pr_update import pr_update

__all__ = ["default_interpret", "pull_sum_kernels", "update_ranks_kernel",
           "linf_delta", "pr_update", "ell_pull", "csr_block_pull"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pull_sum_kernels(dg, c: jnp.ndarray, *, vt: int = 512,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed pull_sum over the hybrid layout (cf. core.pagerank.pull_sum)."""
    interpret = default_interpret() if interpret is None else interpret
    low = ell_pull(c, dg.ell_idx, dg.ell_mask, vt=vt, interpret=interpret)
    hi = csr_block_pull(c, dg.hi_tiles, dg.hi_tmask, dg.hi_rowmap,
                        dg.n_hi_cap, interpret=interpret)
    return low.at[dg.hi_ids].add(hi, mode="drop")


def update_ranks_kernel(dg, r: jnp.ndarray, affected: jnp.ndarray, *,
                        alpha: float, tau_f: float, tau_p: float,
                        prune: bool, closed_form: bool, track_frontier: bool,
                        interpret: bool | None = None):
    """Kernel-backed Alg. 3 body: kernel pull + fused pr_update.

    Same contract as core.pagerank.update_ranks.
    """
    interpret = default_interpret() if interpret is None else interpret
    d = dg.out_deg.astype(r.dtype)
    c = r / d
    contrib = pull_sum_kernels(dg, c, interpret=interpret)
    r_new, aff_new, dn, dmax = pr_update(
        contrib, r, dg.out_deg, affected.astype(r.dtype), alpha=alpha,
        tau_f=tau_f, tau_p=tau_p, prune=prune, closed_form=closed_form,
        interpret=interpret)
    aff_out = aff_new > 0 if prune else affected
    dn_out = (dn > 0) if track_frontier else jnp.zeros_like(affected)
    return r_new, aff_out, dn_out, dmax
