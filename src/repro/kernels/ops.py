"""Jit'd wrappers binding the Pallas kernels to the core engine.

``pull_sum_kernels(dg, c)`` is a drop-in ``pull_sum_fn`` for
``core.pagerank``/``core.dynamic``: the degree-bucketed ELL side via the
lane-per-vertex kernel at each bucket's width, high-degree side via the
tiled-CSR kernel. ``update_ranks_kernel`` is the single-pass Alg. 3 body:
per bucket, one fused kernel instance gathers the in-edge contributions
and applies the rank/prune/frontier epilogue before writing — the staged
``contrib [n]`` HBM round-trip between pull and update exists only on the
bucket-less (d_p = 0) layout. ``interpret`` defaults to True on CPU (this
container) and False on TPU, where the kernels compile via Mosaic
(`kernels.common.default_interpret`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import default_interpret
from .csr_block import csr_block_pull
from .ell_bucket_pull import ell_bucket_pull, fused_ell_update
from .ell_pull import ell_pull
from .linf_delta import linf_delta
from .pr_update import pr_update

__all__ = ["default_interpret", "pull_sum_kernels", "update_ranks_kernel",
           "linf_delta", "pr_update", "ell_pull", "ell_bucket_pull",
           "fused_ell_update", "csr_block_pull"]


def pull_sum_kernels(dg, c: jnp.ndarray, *, vt: int = 512,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed pull_sum over the hybrid layout (cf. core.pagerank.pull_sum)."""
    interpret = default_interpret() if interpret is None else interpret
    out = ell_bucket_pull(c, dg.buckets, vt=vt, interpret=interpret)
    hi = csr_block_pull(c, dg.hi_tiles, dg.hi_tmask, dg.hi_rowmap,
                        dg.n_hi_cap, interpret=interpret)
    return out.at[dg.hi_ids].add(hi, mode="drop")


def update_ranks_kernel(dg, r: jnp.ndarray, affected: jnp.ndarray, *,
                        alpha: float, tau_f: float, tau_p: float,
                        prune: bool, closed_form: bool, track_frontier: bool,
                        active=None, interpret: bool | None = None):
    """Kernel-backed Alg. 3 body, single-pass per bucket.

    Same contract as core.pagerank.update_ranks. Each bucket's slot table
    goes through `fused_ell_update` (gather + epilogue in one kernel); the
    high side pulls per-slot sums through the tiled-CSR kernel and runs the
    same epilogue over the slot table. Every vertex lives in exactly one
    bucket or one high slot (self-loops are guaranteed, so in-degree >= 1
    and the d_p = 0 "one format" layout puts every vertex high-side — one
    epilogue serves all layouts), so each output is written exactly once;
    lanes behind sentinel ids are inert and dropped on scatter-back.

    `active` (core.frontier.ActiveFrontier, valid only when its `overflow`
    is False) restricts every kernel grid to the compacted active lists:
    per-bucket slot lists for the ELL side, the active hi-slot/CSR-tile
    lists for the high side. Rows off the lists keep rank/affected
    untouched and contribute no delta_N / L-inf — identical outputs to the
    full sweep whenever `active` covers the affected set.
    """
    interpret = default_interpret() if interpret is None else interpret
    n = r.shape[0]
    inv_n = 1.0 / n
    dt = r.dtype
    deg = dg.out_deg.astype(dt)
    c = r / deg
    aff_f = affected.astype(dt)

    r_new = r
    aff_new_f = aff_f
    dn_f = jnp.zeros_like(aff_f)
    dmax = jnp.zeros((), dt)
    b_sel = active.bucket_sel if active is not None \
        else (None,) * len(dg.buckets)
    for blk, sel in zip(dg.buckets, b_sel):
        rows = blk.rows if sel is None \
            else jnp.take(blk.rows, sel, mode="fill", fill_value=n)
        r_b = jnp.take(r, blk.rows, mode="fill", fill_value=1.0)
        d_b = jnp.take(deg, blk.rows, mode="fill", fill_value=1.0)
        a_b = jnp.take(aff_f, blk.rows, mode="fill", fill_value=0.0)
        rb, ab, db, pb = fused_ell_update(
            c, blk.idx, blk.mask, r_b, d_b, a_b, alpha=alpha, inv_n=inv_n,
            tau_f=tau_f, tau_p=tau_p, prune=prune, closed_form=closed_form,
            active=sel, interpret=interpret)
        r_new = r_new.at[rows].set(rb, mode="drop")
        aff_new_f = aff_new_f.at[rows].set(ab, mode="drop")
        dn_f = dn_f.at[rows].set(db, mode="drop")
        dmax = jnp.maximum(dmax, pb)

    hi_sums = csr_block_pull(
        c, dg.hi_tiles, dg.hi_tmask, dg.hi_rowmap, dg.n_hi_cap,
        tile_sel=active.tile_sel if active is not None else None,
        interpret=interpret)
    if active is not None:
        # epilogue over the k_h active hi slots only, scattered back through
        # their vertex ids (sentinel lanes dropped)
        ids = jnp.take(dg.hi_ids, active.hi_sel, mode="fill", fill_value=n)
        hi_sums = jnp.take(hi_sums, active.hi_sel, mode="fill",
                           fill_value=0.0)
    else:
        ids = dg.hi_ids
    r_h = jnp.take(r, ids, mode="fill", fill_value=1.0)
    d_h = jnp.take(deg, ids, mode="fill", fill_value=1.0)
    a_h = jnp.take(aff_f, ids, mode="fill", fill_value=0.0)
    rh, ah, dh, ph = pr_update(
        hi_sums, r_h, d_h, a_h, alpha=alpha, inv_n=inv_n, tau_f=tau_f,
        tau_p=tau_p, prune=prune, closed_form=closed_form,
        interpret=interpret)
    r_new = r_new.at[ids].set(rh, mode="drop")
    aff_new_f = aff_new_f.at[ids].set(ah, mode="drop")
    dn_f = dn_f.at[ids].set(dh, mode="drop")
    dmax = jnp.maximum(dmax, ph)

    aff_out = aff_new_f > 0 if prune else affected
    dn_out = (dn_f > 0) if track_frontier else jnp.zeros_like(affected)
    return r_new, aff_out, dn_out, dmax
