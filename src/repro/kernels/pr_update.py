"""Pallas TPU kernel: fused ``updateRanks`` (paper Alg. 3 body).

Fuses, in one VMEM pass per vertex tile:
  rank formula (Eq. 1 or the self-loop closed form Eq. 2) -> masked write
  + |Δr| tile-partials for the L∞ convergence norm (paper's norm kernel 1)
  + DF-P pruning of the affected set (τ_p)
  + frontier flagging δ_N (τ_f)

On the GPU these are 3-4 passes (update kernel pair + norm kernel pair +
flag updates); here a single kernel emits all five outputs — one write per
vertex per output, atomics-free (benchmarks/bench_fusion.py tracks the
fusion accounting). The in-neighbor reduction itself arrives pre-reduced in
``contrib`` (from ell_pull/csr_block_pull or the XLA gather path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.rank_step import rank_value, relative_change
from .common import resolve_interpret

__all__ = ["pr_update"]


def _kernel(contrib_ref, r_ref, deg_ref, aff_ref,
            rnew_ref, affnew_ref, dn_ref, pmax_ref,
            *, alpha, inv_n, tau_f, tau_p, prune, closed_form):
    r = r_ref[...]
    dt = r.dtype
    contrib = contrib_ref[...]
    d = deg_ref[...].astype(dt)
    aff = aff_ref[...] > 0
    # the shared Eq. 1/Eq. 2 math (core.rank_step) — same formulas the XLA
    # engines use, fused here with the norm partials and flag updates
    c0 = jnp.asarray((1.0 - alpha) * inv_n, dt)
    rv = rank_value(contrib, r, d, alpha=alpha, c0=c0,
                    closed_form=closed_form)
    r_new = jnp.where(aff, rv, r)
    dr, rel = relative_change(r_new, r)
    if prune:
        aff = aff & ~(rel <= tau_p)
    rnew_ref[...] = r_new
    affnew_ref[...] = aff.astype(affnew_ref.dtype)
    dn_ref[...] = (rel > tau_f).astype(dn_ref.dtype)
    pmax_ref[0] = jnp.max(dr)


def pr_update(contrib: jnp.ndarray, r: jnp.ndarray, out_deg: jnp.ndarray,
              affected: jnp.ndarray, *, alpha: float = 0.85,
              inv_n: float | None = None, tau_f: float = 1e-6,
              tau_p: float = 1e-6, prune: bool = True,
              closed_form: bool = True, vt: int = 1024,
              interpret: bool | None = None):
    """Returns (r_new, affected', delta_n, linf_dr). affected is {0,1} f32."""
    interpret = resolve_interpret(interpret)
    n = r.shape[0]
    inv_n = 1.0 / n if inv_n is None else inv_n
    pad = (-n) % vt
    if pad:
        contrib = jnp.pad(contrib, (0, pad))
        r = jnp.pad(r, (0, pad), constant_values=1.0)  # rel=0 on padding
        out_deg = jnp.pad(out_deg, (0, pad), constant_values=1)
        affected = jnp.pad(affected, (0, pad))
    npad = n + pad
    grid = (npad // vt,)
    kern = functools.partial(_kernel, alpha=alpha, inv_n=inv_n, tau_f=tau_f,
                             tau_p=tau_p, prune=prune, closed_form=closed_form)
    r_new, aff_new, dn, pmax = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((vt,), lambda i: (i,))] * 4,
        out_specs=[
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), r.dtype),
            jax.ShapeDtypeStruct((npad,), affected.dtype),
            jax.ShapeDtypeStruct((npad,), affected.dtype),
            jax.ShapeDtypeStruct((grid[0],), r.dtype),
        ],
        interpret=interpret,
    )(contrib, r, out_deg.astype(r.dtype), affected)
    return r_new[:n], aff_new[:n], dn[:n], jnp.max(pmax)
