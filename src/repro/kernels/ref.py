"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function has the exact same signature/semantics as the corresponding
kernel wrapper in ``ops.py``; tests sweep shapes/dtypes and assert_allclose.

NOTE: `pr_update_ref` intentionally does NOT import `core.rank_step` — it
is the independent check on the kernel (which does import the shared
math), so sharing code here would let a bug in `rank_step` cancel out.
The engine-side single-implementation rule applies to engines, not oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ell_pull_ref", "csr_block_pull_ref", "pr_update_ref",
           "linf_delta_ref", "flash_attention_ref"]


def ell_pull_ref(c: jnp.ndarray, ell_idx: jnp.ndarray,
                 ell_mask: jnp.ndarray) -> jnp.ndarray:
    """sum_j c[idx[v, j]] * mask[v, j] — the lane-per-vertex pull."""
    return jnp.sum(jnp.take(c, ell_idx, axis=0) * ell_mask.astype(c.dtype),
                   axis=1)


def csr_block_pull_ref(c: jnp.ndarray, hi_tiles: jnp.ndarray,
                       hi_tmask: jnp.ndarray, hi_rowmap: jnp.ndarray,
                       n_rows: int) -> jnp.ndarray:
    """Per-high-vertex tile sums accumulated by the tile->row map."""
    import jax
    tile_sums = jnp.sum(jnp.take(c, hi_tiles, axis=0)
                        * hi_tmask.astype(c.dtype), axis=1)
    return jax.ops.segment_sum(tile_sums, hi_rowmap, num_segments=n_rows)


def pr_update_ref(contrib: jnp.ndarray, r: jnp.ndarray, out_deg: jnp.ndarray,
                  affected: jnp.ndarray, *, alpha: float, inv_n: float,
                  tau_f: float, tau_p: float, prune: bool, closed_form: bool):
    """Fused rank update (Eq. 1 / Eq. 2) + prune + frontier flag + |Δr|.

    contrib[v] = sum_{u in in(v)} R[u]/|out(u)| (already reduced).
    Returns (r_new, affected', delta_n, max_abs_dr).
    """
    dt = r.dtype
    d = out_deg.astype(dt)
    c0 = jnp.asarray((1.0 - alpha) * inv_n, dt)
    if closed_form:
        rv = (c0 + alpha * (contrib - r / d)) / (1.0 - alpha / d)
    else:
        rv = c0 + alpha * contrib
    aff = affected > 0
    r_new = jnp.where(aff, rv, r)
    dr = jnp.abs(r_new - r)
    rel = dr / jnp.maximum(r_new, r)
    if prune:
        aff = aff & ~(rel <= tau_p)
    delta_n = rel > tau_f
    return (r_new, aff.astype(affected.dtype), delta_n.astype(affected.dtype),
            jnp.max(dr))


def linf_delta_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(a - b))


def flash_attention_ref(q, k, v, *, causal=True):
    """Exact softmax attention. q [BH,S,D]; k,v [BH,T,D]."""
    import math
    s = jnp.einsum("bqd,btd->bqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqt,btd->bqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
