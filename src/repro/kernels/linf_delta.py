"""Pallas TPU kernel pair: L∞-norm of a rank difference (paper's convergence
detection). Stage 1: per-tile partial max of |a - b| across the grid.
Stage 2: single-program reduction of the partials buffer. Mirrors the paper's
two-kernel design (block partials -> final reduce -> scalar to host)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import resolve_interpret

__all__ = ["linf_delta"]


def _stage1(a_ref, b_ref, out_ref):
    out_ref[0] = jnp.max(jnp.abs(a_ref[...] - b_ref[...]))


def _stage2(p_ref, out_ref):
    out_ref[0] = jnp.max(p_ref[...])


def linf_delta(a: jnp.ndarray, b: jnp.ndarray, *, vt: int = 2048,
               interpret: bool | None = None) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    n = a.shape[0]
    pad = (-n) % vt
    if pad:
        a = jnp.pad(a, (0, pad))
        b = jnp.pad(b, (0, pad))
    npad = n + pad
    grid = (npad // vt,)
    partials = pl.pallas_call(
        _stage1,
        grid=grid,
        in_specs=[pl.BlockSpec((vt,), lambda i: (i,)),
                  pl.BlockSpec((vt,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), a.dtype),
        interpret=interpret,
    )(a, b)
    out = pl.pallas_call(
        _stage2,
        grid=(1,),
        in_specs=[pl.BlockSpec(partials.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), a.dtype),
        interpret=interpret,
    )(partials)
    return out[0]
