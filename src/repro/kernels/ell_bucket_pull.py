"""Pallas TPU kernels for the degree-bucketed ELL low side.

Two entry points:

``ell_bucket_pull``
    Plain pull over every bucket: per bucket, the lane-per-vertex gather
    kernel (`ell_pull`) at that bucket's width, scattered back through the
    bucket's row-id map. Buckets with width w_b do w_b lanes of work per
    row instead of a single global d_p — the padded-slot waste the single
    width layout pays on skewed degree distributions disappears
    (benchmarks/bench_layout.py quantifies it).

``fused_ell_update``
    The single-pass fused iteration kernel: one kernel instance gathers a
    bucket tile's in-edge contributions AND applies the full `updateRanks`
    epilogue (Eq. 1 / Eq. 2 rank formula, DF-P pruning, δ_N flagging, L∞
    partials) before writing. The staged path materializes ``contrib [n]``
    in HBM between the pull kernel and `pr_update`; fusing the epilogue
    into the gather kernel removes that round-trip — each rank is written
    exactly once per iteration and never re-read in between.

VMEM budget per instance (f32, defaults): the resident contribution
vector ``c`` (n·4 B, the dominant term — valid to |V| ≈ 2M on a 16 MB
core), plus one [vt, w_b] idx/mask tile (vt=512, w_b ≤ 64 → ≤ 256 KB)
and six [vt] vectors for the epilogue operands — comfortably inside the
envelope that `ell_pull` already occupies.

Padding discipline (the `pr_update` trick): lanes past a bucket's live
slots carry r = 1, deg = 1, aff = 0, mask = 0 — contrib 0, rank
unchanged, |Δr| = 0 — so they are inert in every output including the
max-partials, and the sentinel row ids drop the writes on scatter-back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.rank_step import rank_value, relative_change
from .common import resolve_interpret
from .ell_pull import ell_pull

__all__ = ["ell_bucket_pull", "fused_ell_update"]


def ell_bucket_pull(c: jnp.ndarray, buckets, *, vt: int = 512,
                    interpret: bool | None = None) -> jnp.ndarray:
    """out[blk.rows[s]] = sum_j c[blk.idx[s, j]] * blk.mask[s, j], over all
    buckets. Sentinel row ids (>= n) are dropped."""
    interpret = resolve_interpret(interpret)
    out = jnp.zeros(c.shape, c.dtype)
    for blk in buckets:
        sums = ell_pull(c, blk.idx, blk.mask, vt=vt, interpret=interpret)
        out = out.at[blk.rows].add(sums, mode="drop")
    return out


def _fused_kernel(c_ref, idx_ref, mask_ref, r_ref, deg_ref, aff_ref,
                  rnew_ref, affnew_ref, dn_ref, pmax_ref,
                  *, alpha, inv_n, tau_f, tau_p, prune, closed_form):
    c = c_ref[...]
    dt = c.dtype
    gathered = jnp.take(c, idx_ref[...], axis=0)      # [vt, w_b] gather
    contrib = jnp.sum(gathered * mask_ref[...].astype(dt), axis=1)
    r = r_ref[...]
    d = deg_ref[...]
    aff = aff_ref[...] > 0
    # same shared Eq. 1/Eq. 2 math as pr_update, applied in-register on the
    # just-computed contributions — no HBM round-trip in between
    c0 = jnp.asarray((1.0 - alpha) * inv_n, dt)
    rv = rank_value(contrib, r, d, alpha=alpha, c0=c0,
                    closed_form=closed_form)
    r_new = jnp.where(aff, rv, r)
    dr, rel = relative_change(r_new, r)
    if prune:
        aff = aff & ~(rel <= tau_p)
    rnew_ref[...] = r_new
    affnew_ref[...] = aff.astype(affnew_ref.dtype)
    dn_ref[...] = (rel > tau_f).astype(dn_ref.dtype)
    pmax_ref[0] = jnp.max(dr)


def fused_ell_update(c: jnp.ndarray, idx: jnp.ndarray, mask: jnp.ndarray,
                     r_rows: jnp.ndarray, deg_rows: jnp.ndarray,
                     aff_rows: jnp.ndarray, *, alpha: float, inv_n: float,
                     tau_f: float, tau_p: float, prune: bool,
                     closed_form: bool, vt: int = 512,
                     active: jnp.ndarray | None = None,
                     interpret: bool | None = None):
    """One-pass pull + updateRanks over one bucket's slot table.

    c: [n] contributions (resident); idx/mask: [cap_b, w_b]; r/deg/aff:
    [cap_b] operands pre-gathered at the bucket's row ids (sentinel lanes
    must carry r=1, deg=1, aff=0). Returns per-slot
    (r_new, affected', delta_n, linf_dr-scalar) — the caller scatters the
    first three back through the row-id map.

    With `active` (a compacted [k] active-slot list, sentinel == cap_b —
    core.frontier.ActiveFrontier) the kernel grid iterates over the k
    selected slots only: all five per-slot inputs are pre-gathered at
    `active` (dead lanes land on the inert padding discipline above) and
    the returned vectors are [k]-shaped — the caller scatters back through
    `blk.rows[active]`. Per-call edge work drops from O(cap_b · w_b) to
    O(k · w_b), the frontier·degree bound.
    """
    interpret = resolve_interpret(interpret)
    if active is not None:
        idx = jnp.take(idx, active, axis=0, mode="fill", fill_value=0)
        mask = jnp.take(mask, active, axis=0, mode="fill", fill_value=0.0)
        r_rows = jnp.take(r_rows, active, mode="fill", fill_value=1.0)
        deg_rows = jnp.take(deg_rows, active, mode="fill", fill_value=1.0)
        aff_rows = jnp.take(aff_rows, active, mode="fill", fill_value=0.0)
    cap, w = idx.shape
    dt = c.dtype
    pad = (-cap) % vt
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
        r_rows = jnp.pad(r_rows, (0, pad), constant_values=1.0)
        deg_rows = jnp.pad(deg_rows, (0, pad), constant_values=1.0)
        aff_rows = jnp.pad(aff_rows, (0, pad))
    npad = cap + pad
    grid = (npad // vt,)
    kern = functools.partial(_fused_kernel, alpha=alpha, inv_n=inv_n,
                             tau_f=tau_f, tau_p=tau_p, prune=prune,
                             closed_form=closed_form)
    r_new, aff_new, dn, pmax = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(c.shape, lambda i: (0,)),            # c resident
            pl.BlockSpec((vt, w), lambda i: (i, 0)),
            pl.BlockSpec((vt, w), lambda i: (i, 0)),
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((vt,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((vt,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), dt),
            jax.ShapeDtypeStruct((npad,), dt),
            jax.ShapeDtypeStruct((npad,), dt),
            jax.ShapeDtypeStruct((grid[0],), dt),
        ],
        interpret=interpret,
    )(c, idx, mask, r_rows.astype(dt), deg_rows.astype(dt),
      aff_rows.astype(dt))
    return r_new[:cap], aff_new[:cap], dn[:cap], jnp.max(pmax)
