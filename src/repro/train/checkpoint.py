"""Checkpointing: shard-per-file numpy archives with an atomic JSON manifest.

Design goals (DESIGN.md §8):
  * restart-from-last-commit semantics: the manifest is written LAST via
    os.rename (atomic on POSIX), so a crash mid-save never corrupts the
    latest checkpoint;
  * elasticity: arrays are saved UNSHARDED (host-gathered) so a restart may
    use a different mesh/device count — resharding happens at restore when
    the caller passes shardings;
  * integrity: every tensor file carries a checksum in the manifest; restore
    verifies before use;
  * works for any pytree (params, optimizer state, PageRank (R, δ_V), data
    cursor, PRNG key).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key(i: int) -> str:
    return f"leaf_{i:05d}.npy"


_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16", "int8",
           "uint64", "uint32", "uint16", "uint8", "bool"}
_VIEW = {2: np.uint16, 4: np.uint32, 8: np.uint64, 1: np.uint8}


def _to_native(arr: np.ndarray):
    """numpy can't round-trip ml_dtypes (bfloat16, fp8) through .npy —
    store a byte view and record the true dtype in the manifest."""
    if arr.dtype.name in _NATIVE:
        return arr, arr.dtype.name
    view = np.ascontiguousarray(arr).view(_VIEW[arr.dtype.itemsize])
    return view, arr.dtype.name


def _from_native(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _NATIVE:
        return arr
    import ml_dtypes
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Blocking save. Returns the committed checkpoint path."""
    ckpt = os.path.join(directory, f"step_{step:010d}")
    tmp = ckpt + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "time": time.time(),
                "treedef": str(treedef), "n_leaves": len(leaves),
                "extra": extra or {}, "files": {}}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        native, dtype_name = _to_native(arr)
        path = os.path.join(tmp, _key(i))
        np.save(path, native, allow_pickle=False)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        manifest["files"][_key(i)] = {
            "shape": list(arr.shape), "dtype": dtype_name,
            "sha256_16": digest}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)      # atomic commit
    return ckpt


def list_checkpoints(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name[5:]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None, verify: bool = True):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If `shardings` is given (pytree of NamedSharding),
    leaves are placed sharded — this is the elastic-resize path.

    Returns (tree, extra_dict, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}"
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, shd) in enumerate(zip(leaves, shard_leaves)):
        path = os.path.join(ckpt, _key(i))
        if verify:
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            want = manifest["files"][_key(i)]["sha256_16"]
            if digest != want:
                raise IOError(f"checksum mismatch in {path}")
        arr = np.load(path, allow_pickle=False)
        arr = _from_native(arr, manifest["files"][_key(i)]["dtype"])
        want_shape = tuple(leaf.shape)
        assert arr.shape == want_shape, (arr.shape, want_shape)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out), manifest["extra"],
            step)
