"""End-to-end training loop: data -> jitted train_step -> checkpoint/restart.

Used by examples/train_lm.py (runnable on CPU with a smoke config) and by
launch/train.py (mesh-sharded). The loop is restart-safe: step index, params,
optimizer state and PRNG are in the checkpoint; data is seekable by step.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..data.pipeline import batch_for
from ..models import LMModel
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["train"]


def train(cfg: ArchConfig, *, steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
          mesh=None, log_every: int = 10, seed: int = 0,
          fail_at: Optional[int] = None):
    """Returns (params, metrics_history). `fail_at` injects one simulated
    failure (tested in tests/test_checkpoint.py)."""
    model = LMModel(cfg, mesh=mesh)
    params = model.init_params(jax.random.key(seed))
    opt = model.init_opt(params)
    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt), extra, start = restore_checkpoint(
            ckpt_dir, (params, opt))
    step_fn = jax.jit(model.train_step, donate_argnums=(0, 1))
    history = []
    failed = False
    t0 = time.time()
    s = start
    while s < steps:
        b = {k: jnp.asarray(v) for k, v in
             batch_for(cfg, batch, seq, s, seed).items()}
        if fail_at is not None and s == fail_at and not failed:
            failed = True
            raise RuntimeError(f"injected failure at step {s}")
        params, opt, metrics = step_fn(params, opt, b)
        s += 1
        if s % log_every == 0 or s == steps:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = s
            m["sec"] = time.time() - t0
            history.append(m)
        if ckpt_dir and (s % ckpt_every == 0 or s == steps):
            save_checkpoint(ckpt_dir, s, (params, opt))
    return params, history
