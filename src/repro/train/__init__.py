from .checkpoint import (save_checkpoint, restore_checkpoint, latest_step,
                         list_checkpoints)
from .elastic import RunState, run_with_restarts, elastic_pagerank_resume
from .loop import train

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints", "RunState", "run_with_restarts",
           "elastic_pagerank_resume", "train"]
