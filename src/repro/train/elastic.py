"""Fault tolerance & elastic scaling policy.

A step is a pure function of (checkpoint, data cursor); the launcher treats
any failure as "restore last commit and continue", and a device-count change
as "rebuild mesh + reshard at restore" (checkpoints are stored unsharded, see
checkpoint.py). For the PageRank engine, elasticity additionally requires
host repartitioning of the graph (build_sharded is a pure function of
(graph, nd)) — `elastic_pagerank_resume` below does exactly that.

Straggler mitigation: synchronous SPMD steps are bounded by the slowest
shard; the knobs provided are (a) `delta_every` — run k PageRank iterations
between convergence all-reduces (k-step async tolerance: trades up to k-1
surplus iterations for k× fewer host syncs), and (b) even-degree
partitioning: build_sharded assigns contiguous vertex blocks, and the
hybrid layout's tile padding equalizes per-shard edge work (power-law skew is
absorbed by the tile count, not the vertex count).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..core.graph import Graph
from ..core.distributed import build_sharded
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["RunState", "run_with_restarts", "elastic_pagerank_resume"]


@dataclasses.dataclass
class RunState:
    step: int
    tree: Any
    extra: dict


def run_with_restarts(step_fn: Callable[[RunState], RunState],
                      init_fn: Callable[[], RunState],
                      ckpt_dir: str, *, total_steps: int,
                      ckpt_every: int = 50,
                      max_restarts: int = 3,
                      fail_injector: Optional[Callable[[int], None]] = None
                      ) -> RunState:
    """Generic restartable loop: restores the latest commit if present, runs
    `step_fn` until `total_steps`, checkpoints every `ckpt_every`, and on an
    exception restores and continues (up to max_restarts). `fail_injector`
    lets tests simulate node failures at chosen steps."""
    restarts = 0
    state = None
    while True:
        try:
            if state is None:
                last = latest_step(ckpt_dir)
                if last is not None:
                    proto = init_fn()
                    tree, extra, step = restore_checkpoint(ckpt_dir,
                                                           proto.tree)
                    state = RunState(step=step, tree=tree, extra=extra)
                else:
                    state = init_fn()
            while state.step < total_steps:
                if fail_injector is not None:
                    fail_injector(state.step)
                state = step_fn(state)
                if state.step % ckpt_every == 0 or state.step == total_steps:
                    save_checkpoint(ckpt_dir, state.step, state.tree,
                                    state.extra)
            return state
        except (RuntimeError, IOError) as e:          # simulated node failure
            restarts += 1
            if restarts > max_restarts:
                raise
            state = None                              # force restore


def elastic_pagerank_resume(g: Graph, ckpt_dir: str, new_nd: int,
                            d_p: int = 64, tile: int = 1024):
    """Resume PageRank under a different device count: rebuild the sharded
    layout for `new_nd` and reshape the checkpointed dense rank/flag vectors
    into the new (nd, n_loc) layout. Returns (sharded_graph, r, dv)."""
    sg = build_sharded(g, new_nd, d_p=d_p, tile=tile)
    proto = {"r": jax.ShapeDtypeStruct((g.n,), np.float64),
             "dv": jax.ShapeDtypeStruct((g.n,), np.bool_)}
    tree, extra, step = restore_checkpoint(ckpt_dir, proto)
    n_pad = sg.nd * sg.n_loc
    r = np.zeros(n_pad, np.float64)
    r[:g.n] = np.asarray(tree["r"])
    dv = np.zeros(n_pad, bool)
    dv[:g.n] = np.asarray(tree["dv"])
    return sg, r.reshape(sg.nd, sg.n_loc), dv.reshape(sg.nd, sg.n_loc)
