"""DeepSeek-V3 671B [arXiv:2412.19437; hf]: MLA, 1 shared + 256 routed top-8.

Assigned spec: 61L d_model=7168 128H (kv=128) expert d_ff=2048 vocab=129280,
MoE 256e top-8. First 3 layers are dense MLPs (d_ff 18432, per the paper);
MTP head omitted (training-objective add-on, not a structural layer).
Adafactor: AdamW m/v at 671B does not fit a 256-chip v5e pod (see DESIGN.md).
"""
from .base import ArchConfig, MLACfg, MoECfg, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280, head_dim=128,
    prefix=("mla_dense",) * 3, pattern=("mla_moe",), repeats=58,
    moe=MoECfg(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
               router="sigmoid"),
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
               qk_rope_dim=64, v_head_dim=128),
    rope_theta=10_000.0, optimizer="adafactor", microbatch=16, grad_accum_dtype="bfloat16",
))
