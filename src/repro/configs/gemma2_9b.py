"""Gemma-2 9B [arXiv:2408.00118; hf]: alternating local(4096)/global attention,
attn/logit soft-capping, GeGLU, sandwich norms, sqrt(d) embedding scale.

42L d_model=3584 16H (GQA kv=8, head_dim 256) d_ff=14336 vocab=256000.
long_500k is SKIPPED: global layers are full attention (DESIGN.md §5).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab=256_000, head_dim=256,
    pattern=("attn_local", "attn_global"), repeats=21,
    window=4096, attn_softcap=50.0, logit_softcap=30.0,
    mlp="geglu", post_norm=True, embed_scale=True,
))
