"""Assigned input-shape set for the LM-family architectures (40 cells).

train_4k    : train_step,  seq 4096,    global_batch 256
prefill_32k : prefill_step, seq 32768,  global_batch 32
decode_32k  : decode_step (1 new token, KV cache of 32768), global_batch 128
long_500k   : decode_step (1 new token, state/cache at 524288), batch 1
              — sub-quadratic archs only (DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses

from .base import ArchConfig

__all__ = ["ShapeSpec", "SHAPES", "shape_applies", "cells"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applies(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skip(full-attn): quadratic/unbounded KV at 500k"
    return True, ""


def cells(configs: list[ArchConfig]) -> list[tuple[ArchConfig, ShapeSpec]]:
    return [(c, s) for c in configs for s in SHAPES.values()]
