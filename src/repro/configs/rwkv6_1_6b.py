"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892; unverified]: attention-free,
data-dependent per-channel decay, token-shift LoRA mixing.

24L d_model=2048 d_ff=7168 vocab=65536; wkv head size 64 (32 heads).
Constant-size recurrent state => runs long_500k.
"""
from .base import ArchConfig, RecCfg, register

CONFIG = register(ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65_536, head_dim=64,
    pattern=("rwkv",), rope="none",
    rec=RecCfg(head_dim=64, chunk=64),
    sub_quadratic=True,
))
