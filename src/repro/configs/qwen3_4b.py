"""Qwen3 4B [hf:Qwen/Qwen3-8B family; hf]: per-head QK-RMSNorm, GQA, no bias.

36L d_model=2560 32H (GQA kv=8, head_dim 128) d_ff=9728 vocab=151936.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151_936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
))
