"""Per-architecture configs (assigned pool) + shape specs + registry."""
from .base import (ArchConfig, MoECfg, MLACfg, RecCfg, get_config,
                   list_configs, register, smoke_config)
from .shapes import SHAPES, ShapeSpec, cells, shape_applies

__all__ = ["ArchConfig", "MoECfg", "MLACfg", "RecCfg", "get_config",
           "list_configs", "register", "smoke_config", "SHAPES", "ShapeSpec",
           "cells", "shape_applies"]
