"""MusicGen-large [arXiv:2306.05284; hf]: decoder-only transformer over
EnCodec tokens. 48L d_model=2048 32H (MHA kv=32, head_dim 64) d_ff=8192
vocab=2048. Modality frontend (EnCodec) is a STUB: input_specs() provides
precomputed frame embeddings; sinusoidal positions, LayerNorm, GELU MLP.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, head_dim=64,
    rope="sinusoidal", mlp="gelu", norm="layernorm",
    embed_inputs=True,
))
