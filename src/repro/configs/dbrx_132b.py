"""DBRX 132B [hf:databricks/dbrx-base; unverified]: 16-expert top-4 MoE.

40L d_model=6144 48H (GQA kv=8) expert d_ff=10752 vocab=100352.
"""
from .base import ArchConfig, MoECfg, register

CONFIG = register(ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128,
    pattern=("attn_moe",),
    moe=MoECfg(n_experts=16, top_k=4, d_ff_expert=10752),
    rope_theta=500_000.0, qkv_bias=False,
    optimizer="adafactor", microbatch=16, grad_accum_dtype="bfloat16",
))
