"""RecurrentGemma 2B (Griffin) [arXiv:2402.19427; hf]: RG-LRU recurrent blocks
+ local attention, 2:1 ratio, temporal conv width 4, GeGLU.

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
window 2048. Bounded state (window + LRU) => runs long_500k.
"""
from .base import ArchConfig, RecCfg, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256_000, head_dim=256,
    pattern=("rec", "rec", "attn_local"), repeats=8, suffix=("rec", "rec"),
    window=2048, mlp="geglu",
    rec=RecCfg(lru_width=2560, conv_width=4),
    sub_quadratic=True,
))
