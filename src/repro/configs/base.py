"""Architecture config system + registry.

One ``ArchConfig`` per assigned architecture (see files in this package).
``layout`` describes the layer stacking as (prefix, pattern × repeats, suffix)
so the transformer stack can lax.scan the repeated pattern (small HLO, fast
SPMD compiles) and unroll only the irregular prefix/suffix layers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "MoECfg", "MLACfg", "RecCfg", "register", "get_config",
           "list_configs", "smoke_config"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    router: str = "softmax"      # "softmax" | "sigmoid" (DeepSeek-V3)
    n_groups: int = 0            # DS-V3 node-limited routing: expert groups
    group_top: int = 0           # ... tokens routed to <= group_top groups
    dispatch_dtype: str = "bfloat16"   # "float8_e4m3fn": fp8 EP dispatch


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RecCfg:
    """Recurrent block config (RG-LRU / RWKV6)."""
    lru_width: Optional[int] = None   # defaults to d_model
    conv_width: int = 4               # RG-LRU temporal conv
    head_dim: int = 64                # rwkv6 wkv head size
    chunk: int = 64                   # chunked-recurrence length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # layer layout: prefix + pattern*repeats + suffix (kinds; see models/)
    prefix: Tuple[str, ...] = ()
    pattern: Tuple[str, ...] = ("attn",)
    repeats: Optional[int] = None           # default: fill n_layers
    suffix: Tuple[str, ...] = ()
    # attention details
    rope_theta: float = 10_000.0
    rope: str = "rope"           # rope|mrope|sinusoidal|none
    window: Optional[int] = None            # local-attention window
    attn_softcap: Optional[float] = None    # gemma2
    logit_softcap: Optional[float] = None   # gemma2
    qkv_bias: bool = False                  # qwen2
    qk_norm: bool = False                   # qwen3
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # qwen2-vl (t, h, w)
    mlp: str = "swiglu"          # swiglu|geglu|gelu
    norm: str = "rmsnorm"        # rmsnorm|layernorm
    post_norm: bool = False                 # gemma2 sandwich norms
    embed_scale: bool = False               # gemma2 sqrt(d) embed scaling
    embed_inputs: bool = False              # audio/vlm: frontend stub feeds embeddings
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    rec: Optional[RecCfg] = None
    # training / runtime
    dtype: str = "bfloat16"
    optimizer: str = "adamw"     # adamw|adafactor
    microbatch: int = 16         # global microbatch size for grad accumulation
    attn_chunk: int = 1024       # chunked-attention block size
    kv_cache_dtype: str = "bfloat16"        # or "int8" (quantized decode cache)
    grad_accum_dtype: str = "float32"       # bf16 for the MoE giants (memory)
    sub_quadratic: bool = False  # eligible for long_500k
    # --- distribution levers (EXPERIMENTS.md §Perf hillclimbs) ---
    zero1: bool = False          # shard grad accum + opt state over 'data'
    seq_parallel: bool = False   # shard layer-boundary activations' S over 'model'
    pure_dp: bool = False        # batch over ALL mesh axes, weights replicated
    shard_cache_t: bool = False  # decode cache: shard T over 'model'

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[Tuple[str, ...], Tuple[str, ...], int,
                                   Tuple[str, ...]]:
        """(prefix, pattern, repeats, suffix) with repeats resolved."""
        rest = self.n_layers - len(self.prefix) - len(self.suffix)
        reps = self.repeats
        if reps is None:
            assert rest % len(self.pattern) == 0, \
                f"{self.name}: {rest} layers not divisible by pattern " \
                f"{self.pattern}"
            reps = rest // len(self.pattern)
        assert len(self.prefix) + reps * len(self.pattern) + len(self.suffix) \
            == self.n_layers
        return self.prefix, self.pattern, reps, self.suffix


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import side-effect registration
    from . import (deepseek_v3_671b, dbrx_132b, gemma2_9b, qwen2_1_5b,  # noqa
                   qwen3_4b, smollm_360m, rwkv6_1_6b, recurrentgemma_2b,
                   musicgen_large, qwen2_vl_2b)


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: few layers, thin dims,
    tiny vocab/experts — keeps every structural feature of the arch."""
    pre, pat, reps, suf = cfg.layer_kinds()
    n_layers = len(pre) + len(pat) + len(suf)  # one pattern repeat
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, min(cfg.n_heads, 4))
    heads = (heads // kv) * kv or kv
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers, repeats=1,
        d_model=64, n_heads=heads, n_kv_heads=kv, d_ff=128,
        vocab=128, head_dim=16, microbatch=2, attn_chunk=32,
        mrope_sections=(2, 3, 3),
        window=min(cfg.window, 16) if cfg.window else None,
        dtype="float32",
    )
    if cfg.moe:
        # capacity_factor covers every token: token drops are legitimate in
        # training but would break the decode-vs-full parity smoke test
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=32,
            capacity_factor=8.0)
    if cfg.mla:
        changes["mla"] = MLACfg(q_lora_rank=32, kv_lora_rank=16,
                                qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.rec:
        changes["rec"] = dataclasses.replace(
            cfg.rec, lru_width=64 if cfg.rec.lru_width else None,
            head_dim=16, chunk=8)
    return dataclasses.replace(cfg, **changes)
