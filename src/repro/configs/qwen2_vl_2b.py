"""Qwen2-VL 2B [arXiv:2409.12191; hf]: qwen2 backbone with M-RoPE
(temporal/height/width rotary sections). Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings + 3-axis position ids.

28L d_model=1536 12H (GQA kv=2, head_dim 128) d_ff=8960 vocab=151936.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151_936, head_dim=128,
    qkv_bias=True, rope="mrope", rope_theta=1_000_000.0,
    embed_inputs=True,
))
