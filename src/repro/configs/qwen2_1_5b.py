"""Qwen2 1.5B [arXiv:2407.10671; hf]: GQA with QKV bias.

28L d_model=1536 12H (GQA kv=2, head_dim 128) d_ff=8960 vocab=151936.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151_936, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0,
))
