"""Incrementally maintained sharded snapshots — multi-device streaming.

``ShardedSnapshot`` is the multi-device sibling of ``DeviceSnapshot``: it
owns the stacked per-shard hybrid layout of the current graph G^t (the
``ShardedGraph`` consumed by ``core.distributed``) and applies a canonical
``Delta`` *in place* — O(|Δ| · d_p) host bookkeeping on per-shard
``_HalfLayout`` mirrors plus O(touched rows) scatters into the stacked
device arrays — instead of the O(|E|) re-partition + full restage
(`apply_batch` + `build_sharded`) the static sharded pipeline pays per
batch (DESIGN.md §7).

Reuse, not reimplementation: each shard's host mirror IS the single-device
`_HalfLayout` machinery (bucketed-ELL fill-cursor edits, per-bucket and
tile free lists, degree-crossing migration with hysteresis — between
buckets and across the d_p boundary) instantiated on that shard's
`build_hybrid_rows` block — row ids local, stored column ids global. Only
the device residency differs: arrays are stacked [nd, ...] so shard_map can
consume them, and the refresh scatters land at [shard, rows].

Only the pull orientation is maintained. The 1-D distributed DF-P engine
expands its frontier by pulling the all-gathered δ_N through the same pull
layout (no forward orientation exists at this scale), so half the
maintenance work of the single-device snapshot simply disappears.

Capacity discipline matches DeviceSnapshot: per-shard hi/tile caps are pow2
with headroom, shared across shards (stacking needs equal shapes), and
never shrink on rebuild — only genuine pow2 growth changes device shapes /
retriggers jit. Rebuild fallback (capacity exhaustion, fragmentation over
budget, batch above the cost crossover) routes through
`graph_from_sorted_keys` + per-shard `build_hybrid_rows` at fixed caps.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributed import (ShardedGraph, shard_block_rows, shard_bounds,
                                sharded_need)
from ..core.graph import (Graph, build_hybrid_rows, choose_bucket_widths,
                          edge_keys, graph_from_sorted_keys, next_pow2)
from ..core.pagerank import EllBlock
from ..obs.flight import get_flight
from ..obs.spans import get_registry as _obs
from .delta import Delta
from .snapshot import (CapacityError, SnapshotStats, _HalfLayout, _pad_rows,
                       _scatter_1d, apply_net_delta, rebuild_reason)

__all__ = ["ShardedSnapshot"]


@jax.jit
def _scatter_shard_rows(arr, s, rows, vals):
    """arr [nd, R, ...] <- vals at [s, rows]; rows pre-padded (duplicates OK,
    padded lanes re-write identical values)."""
    return arr.at[s, rows].set(vals)


class ShardedSnapshot:
    """Stacked per-shard hybrid layouts of G^t, maintained incrementally.

    Exposes `.sg` — the `ShardedGraph` the distributed engines accept — and
    the same `apply(delta) -> SnapshotStats` lifecycle as `DeviceSnapshot`.
    Vertex v lives on shard `v // n_loc` at local row `v % n_loc`
    (contiguous blocks, identical to `build_sharded`).
    """

    def __init__(self, g: Graph, nd: int, d_p: int = 64, tile: int = 256,
                 hi_headroom: float = 2.0, tile_headroom: float = 2.0,
                 rebuild_threshold: float = 0.05, frag_budget: float = 0.6,
                 low_water: Optional[int] = None):
        self.n = g.n
        self.nd = nd
        self.n_pad = ((g.n + nd - 1) // nd) * nd
        self.n_loc = self.n_pad // nd
        self.d_p, self.tile = d_p, tile
        self.rebuild_threshold = rebuild_threshold
        self.frag_budget = frag_budget
        self._low_water = low_water
        self._hi_headroom, self._tile_headroom = hi_headroom, tile_headroom
        src, dst = g.edges()
        self._keys = np.sort(edge_keys(g.n, src, dst))
        self._indeg = g.in_degree().astype(np.int64)
        self._outdeg = g.out_degree().astype(np.int64)
        # valid is static: the vertex set never changes across the stream
        valid = np.zeros(self.n_pad, bool)
        valid[:self.n] = True
        self._dev_valid = jnp.asarray(valid.reshape(nd, self.n_loc))
        self._adopt(g)
        self._last_rebuild_reason = ""

    # -- construction / rebuild ---------------------------------------------

    def _caps_for(self, indeg: np.ndarray,
                  widths: Optional[tuple] = None) -> dict:
        """Worst-shard bucket/high/tile needs, pow2 with headroom (caps are
        shared across shards — stacking needs equal shapes). Widths are
        chosen once from the global in-degree histogram and then frozen
        across rebuilds (passed back in); only caps may grow."""
        if widths is None:
            widths = choose_bucket_widths(indeg, self.d_p)
        # band=True: caps must cover the hysteresis band each bucket can
        # accumulate under streaming, not just the placement census
        need_hi, need_t, need_b = sharded_need(indeg, self.nd, self.n_loc,
                                               self.d_p, self.tile, widths,
                                               band=True)
        return dict(
            hi_cap=next_pow2(int(need_hi * self._hi_headroom), 8),
            t_cap=next_pow2(int(need_t * self._tile_headroom), 8),
            widths=tuple(widths),
            bucket_caps=tuple(next_pow2(int(nb * self._hi_headroom), 8)
                              for nb in need_b))

    def _adopt(self, g: Graph, caps: Optional[dict] = None) -> None:
        """(Re)build every shard's half from a host Graph at fixed caps."""
        caps = caps or self._caps_for(self._indeg)
        self._caps = caps
        self._halves: List[_HalfLayout] = []
        for s in range(self.nd):
            off, dat = shard_block_rows(g, s, self.n_loc)
            hr = build_hybrid_rows(off, dat, d_p=self.d_p, tile=self.tile,
                                   n_rows=self.n_loc,
                                   n_hi_cap=caps["hi_cap"],
                                   t_cap=caps["t_cap"],
                                   widths=caps["widths"],
                                   bucket_caps=caps["bucket_caps"])
            lo, hi = shard_bounds(s, self.n_loc, self.n)
            row_deg = np.zeros(self.n_loc, np.int64)
            row_deg[:hi - lo] = self._indeg[lo:hi]
            half = _HalfLayout(hr, row_deg, stage_device=False)
            if self._low_water is not None:
                half.low_water = self._low_water
            self._halves.append(half)
        self._restack()

    def _restack(self) -> None:
        # stacked device residency (copies: the mirrors mutate in place) —
        # adopt/rebuild and checkpoint-restore both end here
        self.dev_buckets: List[EllBlock] = [
            EllBlock(
                rows=jnp.asarray(
                    np.stack([h.bk_rows[bi] for h in self._halves])),
                idx=jnp.asarray(
                    np.stack([h.bk_idx[bi] for h in self._halves])),
                mask=jnp.asarray(
                    np.stack([h.bk_mask[bi] for h in self._halves])))
            for bi in range(len(self._caps["widths"]))]
        self.dev_hi_tiles = jnp.asarray(
            np.stack([h.hi_tiles for h in self._halves]))
        self.dev_hi_tmask = jnp.asarray(
            np.stack([h.hi_tmask for h in self._halves]))
        self.dev_hi_rowmap = jnp.asarray(
            np.stack([h.hi_rowmap for h in self._halves]))
        self.dev_hi_pos = jnp.asarray(
            np.stack([h.hi_ids for h in self._halves]))
        outdeg = np.ones(self.n_pad, np.int32)
        outdeg[:self.n] = self._outdeg
        self._dev_outdeg = jnp.asarray(outdeg.reshape(self.nd, self.n_loc))

    def _rebuild(self, reason: str) -> None:
        caps = self._caps_for(self._indeg, widths=self._caps["widths"])
        # never shrink: keep stacked shapes stable unless we *must* grow
        # (widths stay frozen; bucket_caps grow elementwise)
        caps = dict(
            hi_cap=max(caps["hi_cap"], self._caps["hi_cap"]),
            t_cap=max(caps["t_cap"], self._caps["t_cap"]),
            widths=self._caps["widths"],
            bucket_caps=tuple(max(a, b) for a, b in
                              zip(caps["bucket_caps"],
                                  self._caps["bucket_caps"])),
        )
        self._adopt(self.graph(), caps)
        self._last_rebuild_reason = reason

    # -- queries -------------------------------------------------------------

    @property
    def m(self) -> int:
        return int(self._keys.size)

    @property
    def sg(self) -> ShardedGraph:
        return ShardedGraph(
            buckets=tuple(self.dev_buckets),
            hi_pos=self.dev_hi_pos, hi_tiles=self.dev_hi_tiles,
            hi_tmask=self.dev_hi_tmask, hi_rowmap=self.dev_hi_rowmap,
            out_deg=self._dev_outdeg, valid=self._dev_valid, n_true=self.n)

    def graph(self) -> Graph:
        """Materialize the host CSR Graph (verification / rebuild path)."""
        return graph_from_sorted_keys(self.n, self._keys)

    def fragmentation(self) -> float:
        return max(h.tile_waste() for h in self._halves)

    # -- checkpoint state (guard.journal) ------------------------------------

    def state_dict(self) -> tuple:
        """(arrays, extra): complete stacked-snapshot state — edge keys,
        degrees, and every shard's half mirrors + free-list orders under an
        ``s{shard}.`` prefix (see `DeviceSnapshot.state_dict`)."""
        arrays = dict(keys=self._keys, indeg=self._indeg,
                      outdeg=self._outdeg)
        for s, half in enumerate(self._halves):
            arrays.update(half.state_dict(f"s{s}."))
        extra = {"caps": {k: list(v) if isinstance(v, tuple) else int(v)
                          for k, v in self._caps.items()}}
        return arrays, extra

    def load_state(self, arrays: dict, extra: dict) -> None:
        """Restore from ``state_dict`` output: re-adopt at the checkpointed
        capacities, overwrite every shard's mirrors, restack."""
        self._keys = np.ascontiguousarray(arrays["keys"])
        self._indeg = np.ascontiguousarray(arrays["indeg"])
        self._outdeg = np.ascontiguousarray(arrays["outdeg"])
        caps = {k: tuple(v) if isinstance(v, list) else int(v)
                for k, v in extra["caps"].items()}
        self._adopt(self.graph(), caps)
        for s, half in enumerate(self._halves):
            half.load_state(arrays, f"s{s}.")
        self._restack()

    # -- the batch-update lifecycle ------------------------------------------

    def apply(self, delta: Delta) -> SnapshotStats:
        """Apply a canonical Δ^t in place; returns per-apply stats.

        Feeds the same obs span/counter names as `DeviceSnapshot.apply`
        (prefix ``snapshot.``) so dashboards see one stream regardless of
        session mode, plus ``snapshot.shard_scatters`` for the stacked-row
        scatter count."""
        obs = _obs()
        t0 = time.perf_counter()
        stats = SnapshotStats()
        with obs.span("snapshot.apply_net_delta"):
            self._keys, (d_s, d_d), (i_s, i_d) = apply_net_delta(
                self._keys, self.n, delta, self._indeg, self._outdeg)
        stats.net_del, stats.net_ins = int(d_s.size), int(i_s.size)

        reason = rebuild_reason(delta.size, self.m, self.fragmentation(),
                                self.rebuild_threshold, self.frag_budget)
        if reason is not None:
            with obs.span("snapshot.rebuild"):
                self._rebuild(reason)
            obs.inc("snapshot.rebuilds")
            obs.inc(f"snapshot.rebuild.{reason.split(':')[0]}")
            get_flight().emit("snapshot.rebuild", reason=reason,
                              sharded=True)
            stats.rebuilt, stats.rebuild_reason = True, reason
            stats.host_s = time.perf_counter() - t0
            return stats

        n_loc = self.n_loc
        mig0 = sum(h.migrations for h in self._halves)
        try:
            # pull orientation: row = destination vertex, entry = source
            with obs.span("snapshot.host_edit"):
                for u, v in zip(d_s.tolist(), d_d.tolist()):
                    self._halves[v // n_loc].delete(v % n_loc, u)
                for u, v in zip(i_s.tolist(), i_d.tolist()):
                    self._halves[v // n_loc].insert(v % n_loc, u)
        except CapacityError as e:
            # mirrors are mid-edit but the key set is complete: rebuild
            with obs.span("snapshot.rebuild"):
                self._rebuild(f"capacity:{e}")
            obs.inc("snapshot.rebuilds")
            obs.inc("snapshot.rebuild.capacity")
            get_flight().emit("snapshot.rebuild", reason=f"capacity:{e}",
                              sharded=True)
            stats.rebuilt, stats.rebuild_reason = True, f"capacity:{e}"
            stats.host_s = time.perf_counter() - t0
            return stats

        stats.migrations = sum(h.migrations for h in self._halves) - mig0
        stats.host_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        with obs.span("snapshot.device_refresh", annotate=True):
            for s, half in enumerate(self._halves):
                dirty = half.drain_dirty()
                tiles = dirty["tiles"]
                js = jnp.asarray(s)
                for bi, slots in enumerate(dirty["bucket_slots"]):
                    if slots.size:
                        at = _pad_rows(slots, next_pow2(slots.size))
                        blk = self.dev_buckets[bi]
                        new_idx = _scatter_shard_rows(
                            blk.idx, js, jnp.asarray(at),
                            jnp.asarray(half.bk_idx[bi][at]))
                        new_mask = _scatter_shard_rows(
                            blk.mask, js, jnp.asarray(at),
                            jnp.asarray(half.bk_mask[bi][at]))
                        self.dev_buckets[bi] = blk._replace(
                            idx=new_idx, mask=new_mask)
                        obs.inc("snapshot.shard_scatters")
                        stats.rows_touched += int(slots.size)
                    # bucket row-id maps, restaged per shard only on
                    # migration (they are small: [cap_b])
                    if dirty["bucket_maps"][bi]:
                        blk = self.dev_buckets[bi]
                        self.dev_buckets[bi] = blk._replace(
                            rows=blk.rows.at[s].set(
                                jnp.asarray(half.bk_rows[bi].copy())))
                if tiles.size:
                    at = _pad_rows(tiles, next_pow2(tiles.size))
                    self.dev_hi_tiles = _scatter_shard_rows(
                        self.dev_hi_tiles, js, jnp.asarray(at),
                        jnp.asarray(half.hi_tiles[at]))
                    self.dev_hi_tmask = _scatter_shard_rows(
                        self.dev_hi_tmask, js, jnp.asarray(at),
                        jnp.asarray(half.hi_tmask[at]))
                    obs.inc("snapshot.shard_scatters")
                # small per-shard 1-D side tables, restaged only when touched
                if dirty["rowmap_dirty"]:
                    self.dev_hi_rowmap = self.dev_hi_rowmap.at[s].set(
                        jnp.asarray(half.hi_rowmap.copy()))
                if dirty["side_dirty"]:
                    self.dev_hi_pos = self.dev_hi_pos.at[s].set(
                        jnp.asarray(half.hi_ids.copy()))
                stats.tiles_touched += int(tiles.size)
            touched = np.unique(np.concatenate([d_s, i_s]))
            if touched.size:
                at = _pad_rows(touched.astype(np.int32),
                               next_pow2(touched.size))
                flat = self._dev_outdeg.reshape(-1)
                flat = _scatter_1d(
                    flat, jnp.asarray(at),
                    jnp.asarray(self._outdeg[at].astype(np.int32)))
                self._dev_outdeg = flat.reshape(self.nd, self.n_loc)
        obs.inc("snapshot.inplace_batches")
        obs.inc("snapshot.rows_touched", stats.rows_touched)
        obs.inc("snapshot.tiles_touched", stats.tiles_touched)
        obs.inc("snapshot.migrations", stats.migrations)
        stats.device_s = time.perf_counter() - t1
        return stats
