"""Batch ingestion for the streaming engine: dedup, coalescing, pow2 padding.

A raw ``BatchUpdate`` may contain duplicate pairs, self-loop deletions (which
the paper's protocol never removes — self-loops are re-added with every
batch), and pairs present in both lists. ``ingest`` canonicalizes it into a
``Delta`` whose deletion/insertion sets are unique and disjoint, matching
``core.graph.apply_batch`` semantics exactly (deletions apply first, then
insertions; so a pair in both lists nets out to "ensure present" — i.e. a
plain insertion).

``Delta.to_device`` pads both sides to shared power-of-two capacities with
the id-``n`` sentinel (dropped by the engines' ``mode="drop"`` scatters), so
the jitted DF-P drivers see only O(log) distinct batch shapes and never
recompile past warmup.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.dynamic import DeviceBatch, batch_to_device
from ..core.graph import (BatchUpdate, edge_keys, keys_to_edges, next_pow2)
from ..guard.validate import validate_batch

__all__ = ["Delta", "ingest", "next_pow2"]


@dataclasses.dataclass(frozen=True)
class Delta:
    """Canonical Δ^t: unique, disjoint deletion/insertion pairs (int32)."""

    n: int
    del_src: np.ndarray
    del_dst: np.ndarray
    ins_src: np.ndarray
    ins_dst: np.ndarray

    @property
    def nd(self) -> int:
        return int(self.del_src.shape[0])

    @property
    def ni(self) -> int:
        return int(self.ins_src.shape[0])

    @property
    def size(self) -> int:
        return self.nd + self.ni

    def to_device(self, pad_to: int | None = None) -> DeviceBatch:
        """Stage as a DeviceBatch, both sides padded to one pow2 capacity."""
        if pad_to is None:
            pad_to = next_pow2(max(self.nd, self.ni))
        b = BatchUpdate(del_src=self.del_src, del_dst=self.del_dst,
                        ins_src=self.ins_src, ins_dst=self.ins_dst)
        return batch_to_device(b, self.n, pad_to=pad_to)


def _unique_pairs(n: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    if src.size == 0:
        return np.zeros(0, np.int64)
    return np.unique(edge_keys(n, src, dst))


def ingest(batch: BatchUpdate, n: int, coalesce: str = "del_first",
           policy: str = "raise") -> Delta:
    """Canonicalize a BatchUpdate into a Delta.

    coalesce="del_first" (default) matches apply_batch: a pair in both lists
    is deleted then inserted, so it survives as an insertion. "cancel" treats
    the pair as insert-then-delete within the batch window (true temporal
    streams) and drops it from both sides.

    Every batch is validated first (guard.validate): ids outside [0, n)
    would silently alias other edges under the ``src*n + dst`` key encoding
    below, corrupting the snapshot. ``policy="raise"`` (default) rejects
    such batches with ``ValidationError``; ``policy="quarantine"`` drops the
    offending pairs (counted in ``guard.quarantined``) and ingests the rest.
    """
    batch, _ = validate_batch(batch, n, policy=policy)
    dk = _unique_pairs(n, batch.del_src, batch.del_dst)
    ik = _unique_pairs(n, batch.ins_src, batch.ins_dst)
    if dk.size:  # self-loops are never deleted (paper §5.1.4)
        ds, dd = keys_to_edges(n, dk)
        dk = dk[ds != dd]
    both = np.intersect1d(dk, ik, assume_unique=True)
    if both.size:
        dk = np.setdiff1d(dk, both, assume_unique=True)
        if coalesce == "cancel":
            ik = np.setdiff1d(ik, both, assume_unique=True)
        elif coalesce != "del_first":
            raise ValueError(f"unknown coalesce mode: {coalesce!r}")
    ds, dd = keys_to_edges(n, dk)
    is_, id_ = keys_to_edges(n, ik)
    return Delta(n=n, del_src=ds, del_dst=dd, ins_src=is_, ins_dst=id_)
