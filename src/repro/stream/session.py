"""StreamSession — chained DF-P PageRank over a continuous update stream.

The session keeps everything resident across batches: ranks, the hybrid
graph layouts (via the incremental ``DeviceSnapshot`` — or the stacked
``ShardedSnapshot`` when a ``mesh`` is given), and the jit caches of the
DF-P engines. ``apply(batch)`` is the full per-batch lifecycle:

  ingest Δ^t  ->  in-place snapshot update  ->  DF-P from previous ranks

choosing between the **compact** engine (frontier-gathered work, right when
the initial frontier is a small fraction of |V|) and the **dense** engine
(full-width masked sweeps, right when the batch is large — and the internal
fallback of the compact engine anyway). The engine handoff mirrors
DESIGN.md §4: capacity guesses never affect correctness, only speed.

Multi-device mode (``mesh=``): ranks live sharded [nd, n_loc], snapshot
maintenance scatters only touched rows of the stacked layout, and every
batch routes through ``distributed_dfp_pagerank`` with the initial frontier
seeded device-side (`initial_affected_sharded`; the engine performs the
paper's initial expansion at iteration 0) — chained multi-device DF-P over
a continuous stream, same lifecycle, same accounting (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compact import df_pagerank_compact, dfp_pagerank_compact
from ..core.distributed import (distributed_dfp_pagerank,
                                distributed_static_pagerank,
                                initial_affected_sharded,
                                sharded_frontier_caps)
from ..core.dynamic import df_pagerank, dfp_pagerank
from ..core.frontier import caps_for, merge_caps
from ..core.graph import BatchUpdate, Graph
from ..core.pagerank import PRParams, init_ranks, static_pagerank
from ..obs.spans import get_registry as _obs
from ..obs.trace import maybe_summary
from .delta import Delta, ingest
from .sharded import ShardedSnapshot
from .snapshot import DeviceSnapshot, SnapshotStats

__all__ = ["StreamSession", "BatchStats", "choose_engine",
           "frontier_estimate"]


def frontier_estimate(delta: Delta, outdeg: np.ndarray) -> int:
    """Initial-frontier size estimate of Δ^t (paper Alg. 5: the first
    expansion marks the out-neighbors of every updated source, plus every
    deletion target) — the one number engine choice and frontier capacity
    planning both key off."""
    srcs = np.unique(np.concatenate([delta.del_src, delta.ins_src]))
    return int(srcs.size) + int(outdeg[srcs].sum()) + int(delta.del_dst.size)


def choose_engine(delta: Delta, outdeg: np.ndarray, n: int,
                  threshold: float) -> str:
    """Dense vs compact, from the *initial frontier estimate*
    (`frontier_estimate`).

    The compact engine sizes its capacity K ≈ 16 · initial frontier and its
    per-iteration cost scales with K; once K approaches |V| it is strictly a
    slower dense sweep (same gathers + nonzero-compactions on top). So
    compaction is only worth entering when the estimated frontier is a small
    fraction of |V| — the oversized case would fall back to dense *inside*
    the compact driver anyway, this skips the detour.
    """
    est = frontier_estimate(delta, outdeg)
    return "compact" if est <= threshold * n else "dense"


@dataclasses.dataclass
class BatchStats:
    """End-to-end accounting for one applied batch."""
    batch_size: int
    engine: str
    iters: int
    ingest_s: float
    snapshot: SnapshotStats
    solve_s: float
    #: per-iteration trace summary (`obs.trace.trace_summary` dict) when the
    #: session was built with ``trace=True``; None otherwise.
    trace: Optional[dict] = None

    @property
    def total_s(self) -> float:
        return (self.ingest_s + self.snapshot.host_s
                + self.snapshot.device_s + self.solve_s)


class StreamSession:
    """Incrementally expanding DF-P PageRank over a stream of batches.

    >>> sess = StreamSession(base_graph)
    >>> for batch in batches:
    ...     ranks = sess.apply(batch)
    >>> ids, vals = sess.topk(10)

    Multi-device: pass ``mesh=jax.make_mesh(...)`` — the session shards the
    snapshot over all mesh devices and chains the 1-D distributed DF-P
    engine instead (``engine``/``prune``/``compact_threshold`` apply only to
    the single-device path; sharded DF-P always prunes).
    """

    def __init__(self, g: Graph, params: Optional[PRParams] = None,
                 d_p: int = 64, tile: int = 256, engine: str = "auto",
                 prune: bool = True, compact_threshold: float = 0.015,
                 snapshot=None, mesh=None, trace: bool = False, **snap_kw):
        if engine not in ("auto", "dense", "compact"):
            raise ValueError(f"unknown engine: {engine!r}")
        #: when True every solve threads an iteration TraceBuffer through the
        #: engine and each BatchStats carries its `trace_summary` dict.
        #: `trace` is a jit static arg, so on/off paths compile separately
        #: and the off path is byte-identical to an untraced session.
        self.trace = trace
        # Session default: frontier thresholds at 1e-9 (vs the one-shot
        # default 1e-6). Chained DF-P re-uses its own output as the next
        # prior, so per-batch frontier truncation error would otherwise
        # accumulate across the stream; 1e-9 keeps every batch within
        # L1 1e-8 of a from-scratch static solve while the frontier still
        # collapses (thresholds are relative changes, not absolutes).
        self.params = params if params is not None else PRParams(
            tau_f=1e-9, tau_p=1e-9)
        self.engine = engine
        self.prune = prune
        self.compact_threshold = compact_threshold
        self.mesh = mesh
        if mesh is not None:
            nd = int(mesh.devices.size)
            self.snap = snapshot if snapshot is not None else ShardedSnapshot(
                g, nd=nd, d_p=d_p, tile=tile, **snap_kw)
        else:
            self.snap = snapshot if snapshot is not None else DeviceSnapshot(
                g, d_p=d_p, tile=tile, **snap_kw)
        self.ranks, self._init_iters = self._static_solve()
        self.history: List[BatchStats] = []
        #: never-shrink FrontierCaps across the stream (None until the first
        #: compacted batch). Growing a capacity re-traces the engine once;
        #: keeping the running elementwise max means a burst batch can only
        #: ever grow it, so the jit cache stays warm for the rest of the
        #: stream (zero recompiles after the high-water mark).
        self._caps = None

    @property
    def n(self) -> int:
        return self.snap.n

    @property
    def m(self) -> int:
        return self.snap.m

    # -- the streaming API ---------------------------------------------------

    def apply(self, batch: BatchUpdate | Delta) -> jnp.ndarray:
        """Apply Δ^t and return the new rank vector (device-resident;
        stacked [nd, n_loc] in mesh mode — see `flat_ranks`)."""
        obs = _obs()
        t0 = time.perf_counter()
        with obs.span("session.ingest"):
            delta = batch if isinstance(batch, Delta) else ingest(
                batch, self.n)
            db = delta.to_device()
        ingest_s = time.perf_counter() - t0

        snap_stats = self.snap.apply(delta)

        t1 = time.perf_counter()
        engine = self._choose_engine(delta)
        obs.inc(f"session.engine.{engine}")
        caps = self._frontier_caps(frontier_estimate(delta,
                                                     self.snap._outdeg))
        with obs.span("session.solve", annotate=True):
            if engine == "sharded":
                dv0, dn0 = initial_affected_sharded(
                    self.snap.nd, self.snap.n_loc, db)
                out = distributed_dfp_pagerank(
                    self.mesh, self.snap.sg, self.ranks, dv0, dn0,
                    self.params, trace=self.trace, frontier_caps=caps)
            elif engine == "compact":
                fn = (dfp_pagerank_compact if self.prune
                      else df_pagerank_compact)
                out = fn(self.snap, None, self.ranks, db, self.params,
                         trace=self.trace)
            else:
                fn = dfp_pagerank if self.prune else df_pagerank
                out = fn(self.snap, self.ranks, db, self.params,
                         trace=self.trace, frontier_caps=caps)
            (r, iters), summary = maybe_summary(out, self.trace)
            r = jax.block_until_ready(r)
        solve_s = time.perf_counter() - t1

        self.ranks = r
        self.history.append(BatchStats(
            batch_size=delta.size, engine=engine, iters=int(iters),
            ingest_s=ingest_s, snapshot=snap_stats, solve_s=solve_s,
            trace=summary))
        return r

    def _frontier_caps(self, est: int):
        """Frontier capacity plan for this batch — the running elementwise
        max over the stream (never-shrink), so capacities only grow at a
        new high-water mark and the engine's jit cache survives every batch
        below it. `frontier.caps_growth` counts the (re-tracing) growth
        events."""
        new = (sharded_frontier_caps(self.snap.sg, est)
               if self.mesh is not None else caps_for(self.snap.dg, est))
        merged = merge_caps(self._caps, new)
        if self._caps is not None and merged != self._caps:
            _obs().inc("frontier.caps_growth")
        self._caps = merged
        return merged

    def _choose_engine(self, delta: Delta) -> str:
        if self.mesh is not None:
            return "sharded"
        if self.engine != "auto":
            return self.engine
        return choose_engine(delta, self.snap._outdeg, self.n,
                             self.compact_threshold)

    def _static_solve(self):
        """From-scratch static solve on the current snapshot, in the
        session's native rank layout (dense [n], or stacked [nd, n_loc] in
        mesh mode). The single place the recipe lives: init vector, engine
        choice and params stay in lock-step across __init__ /
        static_reference / recompute."""
        if self.mesh is None:
            return static_pagerank(self.snap.dg, init_ranks(self.n),
                                   self.params)
        r0 = jnp.full((self.snap.nd, self.snap.n_loc), 1.0 / self.n,
                      init_ranks(1).dtype)
        return distributed_static_pagerank(self.mesh, self.snap.sg, r0,
                                           self.params)

    def _flatten(self, r: jnp.ndarray) -> jnp.ndarray:
        return r if self.mesh is None else jnp.reshape(r, (-1,))[:self.n]

    def flat_ranks(self) -> jnp.ndarray:
        """Current ranks as a dense [n] vector regardless of session mode."""
        return self._flatten(self.ranks)

    def static_reference(self) -> jnp.ndarray:
        """From-scratch static solve on the *current* snapshot, dense [n] —
        the verification anchor for the chained DF-P ranks. Does not touch
        session state."""
        return self._flatten(self._static_solve()[0])

    def topk(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k vertices by rank: (ids [k], ranks [k]), descending."""
        vals, ids = jax.lax.top_k(self.flat_ranks(), k)
        return np.asarray(ids), np.asarray(vals)

    def recompute(self) -> jnp.ndarray:
        """Full static recomputation on the current snapshot (re-sync /
        verification anchor); resets the session's rank state."""
        self.ranks, _ = self._static_solve()
        return self.ranks
