"""StreamSession — chained DF-P PageRank over a continuous update stream.

The session keeps everything resident across batches: ranks, the hybrid
graph layouts (via the incremental ``DeviceSnapshot`` — or the stacked
``ShardedSnapshot`` when a ``mesh`` is given), and the jit caches of the
DF-P engines. ``apply(batch)`` is the full per-batch lifecycle:

  ingest Δ^t  ->  in-place snapshot update  ->  DF-P from previous ranks

choosing between the **compact** engine (frontier-gathered work, right when
the initial frontier is a small fraction of |V|) and the **dense** engine
(full-width masked sweeps, right when the batch is large — and the internal
fallback of the compact engine anyway). The engine handoff mirrors
DESIGN.md §4: capacity guesses never affect correctness, only speed.

Multi-device mode (``mesh=``): ranks live sharded [nd, n_loc], snapshot
maintenance scatters only touched rows of the stacked layout, and every
batch routes through ``distributed_dfp_pagerank`` with the initial frontier
seeded device-side (`initial_affected_sharded`; the engine performs the
paper's initial expansion at iteration 0) — chained multi-device DF-P over
a continuous stream, same lifecycle, same accounting (DESIGN.md §7).

Fault tolerance (``guard=GuardConfig(...)`` — DESIGN.md §13): every raw
batch is validated (raise or quarantine out-of-range pairs), every solve
returns a device-side health word, and an unhealthy solve walks the
escalation ladder — full-budget dense (or sharded) DF-P retry from the
pre-solve ranks, then a static recompute — with ``guard.*`` counters at
each rung. ``journal_dir=`` adds a write-ahead delta journal and (with
``checkpoint_every=K``) periodic full-state checkpoints;
``StreamSession.restore(dir)`` rebuilds the session bit-identically from
the newest checkpoint plus a journal replay.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compact import df_pagerank_compact, dfp_pagerank_compact
from ..core.distributed import (distributed_dfp_pagerank,
                                distributed_static_pagerank,
                                initial_affected_sharded,
                                sharded_frontier_caps)
from ..core.dynamic import df_pagerank, dfp_pagerank
from ..core.frontier import FrontierCaps, caps_for, merge_caps
from ..core.graph import BatchUpdate, Graph, graph_from_sorted_keys
from ..core.pagerank import PRParams, init_ranks, static_pagerank
from ..guard import GuardConfig
from ..guard.health import (HEALTH_OK, H_MASS_DRIFT, MASS_TOL, health_flags)
from ..guard.journal import (DeltaJournal, JournalRecord, journal_path,
                             load_session_checkpoint,
                             save_session_checkpoint)
from ..guard.validate import validate_batch
from ..obs.flight import get_flight
from ..obs.hist import Histogram, SLOConfig, start_profiler, stop_profiler
from ..obs.postmortem import write_bundle
from ..obs.spans import get_registry as _obs
from ..obs.trace import maybe_summary
from .delta import Delta, ingest
from .sharded import ShardedSnapshot
from .snapshot import DeviceSnapshot, SnapshotStats

__all__ = ["StreamSession", "BatchStats", "choose_engine",
           "frontier_estimate"]


def frontier_estimate(delta: Delta, outdeg: np.ndarray) -> int:
    """Initial-frontier size estimate of Δ^t (paper Alg. 5: the first
    expansion marks the out-neighbors of every updated source, plus every
    deletion target) — the one number engine choice and frontier capacity
    planning both key off."""
    srcs = np.unique(np.concatenate([delta.del_src, delta.ins_src]))
    return int(srcs.size) + int(outdeg[srcs].sum()) + int(delta.del_dst.size)


def choose_engine(delta: Delta, outdeg: np.ndarray, n: int,
                  threshold: float) -> str:
    """Dense vs compact, from the *initial frontier estimate*
    (`frontier_estimate`).

    The compact engine sizes its capacity K ≈ 16 · initial frontier and its
    per-iteration cost scales with K; once K approaches |V| it is strictly a
    slower dense sweep (same gathers + nonzero-compactions on top). So
    compaction is only worth entering when the estimated frontier is a small
    fraction of |V| — the oversized case would fall back to dense *inside*
    the compact driver anyway, this skips the detour.
    """
    est = frontier_estimate(delta, outdeg)
    return "compact" if est <= threshold * n else "dense"


@dataclasses.dataclass
class BatchStats:
    """End-to-end accounting for one applied batch."""
    batch_size: int
    engine: str
    iters: int
    ingest_s: float
    snapshot: SnapshotStats
    solve_s: float
    #: per-iteration trace summary (`obs.trace.trace_summary` dict) when the
    #: session was built with ``trace=True``; None otherwise.
    trace: Optional[dict] = None
    #: guard.health word of the FIRST solve attempt (0 = healthy; only
    #: populated on guarded sessions)
    health: int = 0
    #: escalation-ladder rungs walked for this batch (0 = none needed)
    escalations: int = 0
    #: out-of-range pairs dropped by the quarantine policy at ingest
    quarantined: int = 0

    @property
    def total_s(self) -> float:
        return (self.ingest_s + self.snapshot.host_s
                + self.snapshot.device_s + self.solve_s)


def _caps_to_json(caps: Optional[FrontierCaps]):
    if caps is None:
        return None
    return {k: list(v) if isinstance(v, tuple) else int(v)
            for k, v in caps._asdict().items()}


def _caps_from_json(d) -> Optional[FrontierCaps]:
    if d is None:
        return None
    return FrontierCaps(**{k: tuple(v) if isinstance(v, list) else int(v)
                           for k, v in d.items()})


class StreamSession:
    """Incrementally expanding DF-P PageRank over a stream of batches.

    >>> sess = StreamSession(base_graph)
    >>> for batch in batches:
    ...     ranks = sess.apply(batch)
    >>> ids, vals = sess.topk(10)

    Multi-device: pass ``mesh=jax.make_mesh(...)`` — the session shards the
    snapshot over all mesh devices and chains the 1-D distributed DF-P
    engine instead (``engine``/``prune``/``compact_threshold`` apply only to
    the single-device path; sharded DF-P always prunes).

    Fault tolerance: ``guard=GuardConfig(...)`` switches on ingest
    validation, the per-solve health watchdog + escalation ladder and the
    periodic drift audit; ``journal_dir=``/``checkpoint_every=`` add crash
    recovery via ``StreamSession.restore(journal_dir)``.
    """

    def __init__(self, g: Graph, params: Optional[PRParams] = None,
                 d_p: int = 64, tile: int = 256, engine: str = "auto",
                 prune: bool = True, compact_threshold: float = 0.015,
                 snapshot=None, mesh=None, trace: bool = False,
                 guard: Optional[GuardConfig] = None,
                 slo: Optional[SLOConfig] = None,
                 journal_dir: Optional[str] = None,
                 checkpoint_every: int = 0, **snap_kw):
        if engine not in ("auto", "dense", "compact"):
            raise ValueError(f"unknown engine: {engine!r}")
        #: when True every solve threads an iteration TraceBuffer through the
        #: engine and each BatchStats carries its `trace_summary` dict.
        #: `trace` is a jit static arg, so on/off paths compile separately
        #: and the off path is byte-identical to an untraced session.
        self.trace = trace
        # Session default: frontier thresholds at 1e-9 (vs the one-shot
        # default 1e-6). Chained DF-P re-uses its own output as the next
        # prior, so per-batch frontier truncation error would otherwise
        # accumulate across the stream; 1e-9 keeps every batch within
        # L1 1e-8 of a from-scratch static solve while the frontier still
        # collapses (thresholds are relative changes, not absolutes).
        self.params = params if params is not None else PRParams(
            tau_f=1e-9, tau_p=1e-9)
        self.engine = engine
        self.prune = prune
        self.compact_threshold = compact_threshold
        self.mesh = mesh
        self.guard = guard
        self.slo = slo
        self.journal_dir = journal_dir
        self.checkpoint_every = checkpoint_every
        self._snap_kw = dict(snap_kw)
        self._d_p, self._tile = d_p, tile
        if mesh is not None:
            nd = int(mesh.devices.size)
            self.snap = snapshot if snapshot is not None else ShardedSnapshot(
                g, nd=nd, d_p=d_p, tile=tile, **snap_kw)
        else:
            self.snap = snapshot if snapshot is not None else DeviceSnapshot(
                g, d_p=d_p, tile=tile, **snap_kw)
        self.ranks, self._init_iters = self._static_solve()
        self.history: List[BatchStats] = []
        #: never-shrink FrontierCaps across the stream (None until the first
        #: compacted batch). Growing a capacity re-traces the engine once;
        #: keeping the running elementwise max means a burst batch can only
        #: ever grow it, so the jit cache stays warm for the rest of the
        #: stream (zero recompiles after the high-water mark).
        self._caps = None
        #: sequence number of the last journaled batch (noops don't count:
        #: they change no state and are never journaled, so restore() replay
        #: and the live stream stay aligned)
        self._batch_idx = 0
        self._replaying = False
        self._journal = (DeltaJournal(journal_path(journal_dir))
                         if journal_dir is not None else None)
        #: per-session solve-latency histogram (the SLO judges THIS stream's
        #: p99, not the process-wide registry shared across sessions)
        self._solve_hist = Histogram()
        #: profiler-capture state machine: ``_capture_remaining`` batches
        #: still to run under an armed/active trace, ``_capture_active``
        #: while jax.profiler is recording. One automatic arm per session
        #: (``_slo_captured``); re-arm explicitly via `arm_capture`.
        self._capture_remaining = 0
        self._capture_active = False
        self._capture_dir: Optional[str] = None
        self._slo_captured = False
        #: quarantine summary of the most recent non-clean ingest (bundles
        #: embed it: the poisoned batch is usually the story)
        self._last_quarantine: Optional[dict] = None

    @property
    def n(self) -> int:
        return self.snap.n

    @property
    def m(self) -> int:
        return self.snap.m

    # -- the streaming API ---------------------------------------------------

    def apply(self, batch: BatchUpdate | Delta) -> jnp.ndarray:
        """Apply Δ^t and return the new rank vector (device-resident;
        stacked [nd, n_loc] in mesh mode — see `flat_ranks`)."""
        obs = _obs()
        flight = get_flight()
        t0 = time.perf_counter()
        with obs.span("session.ingest"):
            quarantined = 0
            if isinstance(batch, Delta):
                delta = batch
            else:
                policy = (self.guard.policy if self.guard is not None
                          else "raise")
                batch, report = validate_batch(batch, self.n, policy=policy)
                quarantined = report.size
                if quarantined:
                    self._last_quarantine = {
                        "size": int(report.size),
                        "deletions": int(report.del_src.size),
                        "insertions": int(report.ins_src.size)}
                    flight.emit("guard.quarantine", seq=self._batch_idx + 1,
                                dropped=int(report.size))
                delta = ingest(batch, self.n)
            db = delta.to_device() if delta.size else None
        ingest_s = time.perf_counter() - t0

        if delta.size == 0:
            # an empty (or fully-quarantined) Δ changes nothing: skip the
            # snapshot pass, the solve and the journal entirely — the
            # zero-cost no-op every upstream coalescer is entitled to
            obs.inc("session.engine.noop")
            self.history.append(BatchStats(
                batch_size=0, engine="noop", iters=0, ingest_s=ingest_s,
                snapshot=SnapshotStats(), solve_s=0.0,
                quarantined=quarantined))
            return self.ranks

        # write-ahead: the journal record lands BEFORE the delta touches the
        # snapshot, so a crash anywhere past this line replays the batch
        seq = self._batch_idx + 1
        self._journal_append(seq, delta)

        snap_stats = self.snap.apply(delta)

        t1 = time.perf_counter()
        engine = self._choose_engine(delta)
        obs.inc(f"session.engine.{engine}")
        flight.emit("session.engine", seq=seq, engine=engine,
                    size=delta.size)
        caps = self._frontier_caps(frontier_estimate(delta,
                                                     self.snap._outdeg))
        guarded = self.guard is not None
        r_pre = self.ranks
        self._maybe_capture_start()
        with obs.span("session.solve", annotate=True):
            if engine == "sharded":
                dv0, dn0 = initial_affected_sharded(
                    self.snap.nd, self.snap.n_loc, db)
                out = distributed_dfp_pagerank(
                    self.mesh, self.snap.sg, self.ranks, dv0, dn0,
                    self.params, trace=self.trace, frontier_caps=caps,
                    health=guarded)
            elif engine == "compact":
                fn = (dfp_pagerank_compact if self.prune
                      else df_pagerank_compact)
                out = fn(self.snap, None, self.ranks, db, self.params,
                         trace=self.trace, health=guarded)
            else:
                fn = dfp_pagerank if self.prune else df_pagerank
                out = fn(self.snap, self.ranks, db, self.params,
                         trace=self.trace, frontier_caps=caps,
                         health=guarded)
            hw = 0
            if guarded:
                *rest, hw_dev = out
                out = tuple(rest)
                hw = self._apply_mass_tol(int(hw_dev), rest[0])
            (r, iters), summary = maybe_summary(out, self.trace)
            iters = int(iters)
            escalations = 0
            if guarded and hw != HEALTH_OK:
                r, iters, escalations = self._escalate(r_pre, db, hw,
                                                       r, iters,
                                                       summary=summary,
                                                       seq=seq)
            r = jax.block_until_ready(r)
        solve_s = time.perf_counter() - t1
        self._maybe_capture_stop()

        self.ranks = r
        self._batch_idx = seq
        self.history.append(BatchStats(
            batch_size=delta.size, engine=engine, iters=iters,
            ingest_s=ingest_s, snapshot=snap_stats, solve_s=solve_s,
            trace=summary, health=hw, escalations=escalations,
            quarantined=quarantined))
        self._solve_hist.add(solve_s)
        flight.emit("session.batch", seq=seq, engine=engine,
                    size=delta.size, iters=iters,
                    solve_us=round(solve_s * 1e6, 1), health=hw,
                    escalations=escalations)
        self._check_slo()
        if (self.guard is not None and self.guard.audit_every
                and self._batch_idx % self.guard.audit_every == 0):
            self._audit()
        if (self._journal is not None and self.checkpoint_every
                and not self._replaying
                and self._batch_idx % self.checkpoint_every == 0):
            self.checkpoint()
        return self.ranks

    # -- SLO + on-demand profiler capture (DESIGN.md §14) --------------------

    def solve_percentiles(self) -> dict:
        """Percentile snapshot of this session's per-batch solve latency
        (seconds): ``{count, p50_s, p95_s, p99_s, max_s}``."""
        return self._solve_hist.as_dict()

    def arm_capture(self, batches: int, log_dir: Optional[str] = None
                    ) -> None:
        """Arm ``jax.profiler`` trace capture around the next ``batches``
        applies (manual re-arm of the SLO auto-capture)."""
        self._capture_remaining = max(int(batches), 0)
        if log_dir is not None:
            self._capture_dir = log_dir

    def _capture_log_dir(self) -> str:
        if self._capture_dir is not None:
            return self._capture_dir
        if self.slo is not None and self.slo.capture_dir is not None:
            return self.slo.capture_dir
        base = self.journal_dir if self.journal_dir is not None else "."
        return os.path.join(base, "profile")

    def _maybe_capture_start(self) -> None:
        if self._capture_remaining <= 0 or self._capture_active:
            return
        log_dir = self._capture_log_dir()
        if start_profiler(log_dir):
            self._capture_active = True
            _obs().inc("slo.capture.start")
            get_flight().emit("slo.capture.start", dir=log_dir,
                              batches=self._capture_remaining)
        else:
            # profiler unavailable on this backend: disarm rather than
            # retrying (and failing) on every subsequent batch
            self._capture_remaining = 0
            _obs().inc("slo.capture.unavailable")

    def _maybe_capture_stop(self) -> None:
        if not self._capture_active:
            return
        self._capture_remaining -= 1
        if self._capture_remaining > 0:
            return
        self._capture_active = False
        stop_profiler()
        _obs().inc("slo.capture.stop")
        get_flight().emit("slo.capture.stop")

    def _check_slo(self) -> None:
        """Judge the running solve p99 against the session's SLOConfig;
        on breach bump counters, emit a flight event, and (once per
        session) auto-arm profiler capture for the next batches."""
        s = self.slo
        if s is None or self._solve_hist.count < max(int(s.min_samples), 1):
            return
        p99 = self._solve_hist.percentile(99)
        if p99 is None or p99 * 1e6 <= s.solve_p99_us:
            return
        _obs().inc("slo.breach.solve_p99")
        get_flight().emit("slo.breach", metric="solve_p99",
                          p99_us=round(p99 * 1e6, 1),
                          budget_us=s.solve_p99_us)
        if s.capture_batches > 0 and not self._slo_captured:
            self._slo_captured = True
            self.arm_capture(s.capture_batches)

    # -- guard: escalation ladder + drift audit ------------------------------

    def _apply_mass_tol(self, hw: int, r) -> int:
        """Re-judge the H_MASS_DRIFT bit under the guard's ``mass_tol``.

        The engines bake the library default (``health.MASS_TOL``) into
        their jitted health epilogue; a session-level override re-derives
        the bit from the candidate ranks host-side — one O(n) reduction,
        negligible next to the solve. A non-finite mass clears the bit
        (H_NONFINITE already covers that failure)."""
        g = self.guard
        if g is None or g.mass_tol == MASS_TOL:
            return hw
        drift = abs(float(jnp.sum(self._flatten(jnp.asarray(r)))) - 1.0)
        if np.isfinite(drift) and drift > g.mass_tol:
            return hw | H_MASS_DRIFT
        return hw & ~H_MASS_DRIFT

    def _recovery_params(self) -> PRParams:
        if self.guard.recovery_params is not None:
            return self.guard.recovery_params
        # the session's params with the full default iteration budget
        # restored: a chaos-starved max_iter=1 session must still recover
        # with a real solve
        return self.params._replace(max_iter=PRParams().max_iter)

    def _escalate(self, r_pre, db, hw: int, r, iters: int,
                  summary: Optional[dict] = None,
                  seq: Optional[int] = None):
        """Walk the recovery ladder after an unhealthy solve.

        Rung 1 retries the batch with the *recovery* params (full iteration
        budget) from the pre-solve ranks — dense DF-P on single-device
        sessions (the compact engine's own superset), the sharded engine in
        mesh mode. Rung 2 resolves from scratch: a static solve from
        ``init_ranks``, which ignores every piece of possibly-poisoned rank
        state. Each rung's result is accepted only if ITS health word is
        clean; ``retry_budget`` bounds the rungs walked. Returns
        ``(ranks, iters, rungs_walked)`` — on an exhausted budget, the last
        attempt's result (counted in ``guard.escalate.exhausted``) plus a
        post-mortem bundle under `_postmortem_dir` (DESIGN.md §14)."""
        obs = _obs()
        flight = get_flight()
        obs.inc("guard.unhealthy")
        for name in health_flags(hw):
            obs.inc(f"guard.health.{name}")
        rp = self._recovery_params()
        rungs = (["sharded"] if self.mesh is not None else ["dense"])
        rungs.append("recompute")
        walked = 0
        hw2 = hw
        for rung in rungs[:max(int(self.guard.retry_budget), 0)]:
            walked += 1
            obs.inc(f"guard.escalate.{rung}")
            flight.emit("guard.escalate", rung=rung, seq=seq, health=hw)
            if rung == "dense":
                fn = dfp_pagerank if self.prune else df_pagerank
                r, it, hw2 = fn(self.snap, r_pre, db, rp, health=True)
            elif rung == "sharded":
                dv0, dn0 = initial_affected_sharded(
                    self.snap.nd, self.snap.n_loc, db)
                r, it, hw2 = distributed_dfp_pagerank(
                    self.mesh, self.snap.sg, r_pre, dv0, dn0, rp,
                    health=True)
            else:
                r, it, hw2 = self._static_solve(params=rp, health=True)
            iters, hw2 = int(it), self._apply_mass_tol(int(hw2), r)
            if hw2 == HEALTH_OK:
                obs.inc("guard.escalate.success")
                return r, iters, walked
        obs.inc("guard.escalate.exhausted")
        flight.emit("guard.escalate.exhausted", seq=seq, health=int(hw2))
        pdir = self._postmortem_dir()
        if pdir is not None:
            write_bundle(pdir, reason="escalation_exhausted",
                         health=int(hw2), trace=summary,
                         quarantine=self._last_quarantine,
                         journal_seq=seq,
                         extra={"first_health": int(hw),
                                "rungs_walked": walked,
                                "slo": self._solve_hist.as_dict()})
        return r, iters, walked

    def _postmortem_dir(self) -> Optional[str]:
        """Where failure bundles land: ``GuardConfig.postmortem_dir``, else
        the journal directory, else ``$REPRO_POSTMORTEM_DIR``; None disables
        bundle writing (no sensible destination)."""
        if self.guard is not None and self.guard.postmortem_dir is not None:
            return self.guard.postmortem_dir
        if self.journal_dir is not None:
            return self.journal_dir
        return os.environ.get("REPRO_POSTMORTEM_DIR") or None

    def _audit(self) -> None:
        """Every-K-batches drift audit: chained ranks vs a from-scratch
        static solve on the current snapshot. Breaching ``audit_tol`` (L1)
        adopts the static solve — the bounded-staleness backstop chained
        approximation error cannot creep past. The reference runs with the
        *recovery* params: the audit exists to catch degraded session state,
        so its anchor must not inherit a degraded iteration budget."""
        obs = _obs()
        obs.inc("guard.audit.runs")
        r_ref = self._static_solve(params=self._recovery_params())[0]
        l1 = float(jnp.sum(jnp.abs(self.flat_ranks()
                                   - self._flatten(r_ref))))
        resync = l1 > self.guard.audit_tol
        get_flight().emit("guard.audit", seq=self._batch_idx, l1=l1,
                          resync=resync)
        if resync:
            obs.inc("guard.audit.resync")
            self.ranks = r_ref

    # -- guard: journal + checkpoint / restore -------------------------------

    def _journal_append(self, seq: int, delta: Delta) -> None:
        if self._journal is None or self._replaying:
            return
        self._journal.append(JournalRecord(
            seq=seq, n=delta.n,
            del_src=np.asarray(delta.del_src, np.int32),
            del_dst=np.asarray(delta.del_dst, np.int32),
            ins_src=np.asarray(delta.ins_src, np.int32),
            ins_dst=np.asarray(delta.ins_dst, np.int32)))

    def _session_config(self) -> dict:
        g = self.guard
        gd = None
        if g is not None:
            gd = dataclasses.asdict(g)
            gd["recovery_params"] = (list(g.recovery_params)
                                     if g.recovery_params is not None
                                     else None)
        slo = (dataclasses.asdict(self.slo) if self.slo is not None
               else None)
        if slo is not None and slo["solve_p99_us"] == float("inf"):
            slo["solve_p99_us"] = None  # JSON has no inf
        return dict(n=self.n, params=list(self.params),
                    d_p=self._d_p, tile=self._tile, engine=self.engine,
                    prune=self.prune,
                    compact_threshold=self.compact_threshold,
                    trace=self.trace, mesh=self.mesh is not None,
                    checkpoint_every=self.checkpoint_every,
                    guard=gd, slo=slo, snap_kw=dict(self._snap_kw))

    def checkpoint(self) -> str:
        """Write a full-state checkpoint (ranks + snapshot mirrors + config)
        under ``journal_dir``, valid after batch ``_batch_idx``. Atomic via
        train/checkpoint.py's manifest rename."""
        if self.journal_dir is None:
            raise ValueError("session has no journal_dir")
        arrays, snap_extra = self.snap.state_dict()
        arrays = dict(arrays)
        arrays["ranks"] = np.asarray(self.ranks)
        extra = {"snap": snap_extra, "session": self._session_config(),
                 "frontier_caps": _caps_to_json(self._caps)}
        path = save_session_checkpoint(self.journal_dir, self._batch_idx,
                                       arrays, extra)
        get_flight().emit("guard.checkpoint", seq=self._batch_idx,
                          path=path)
        return path

    @classmethod
    def restore(cls, directory: str, mesh=None) -> "StreamSession":
        """Rebuild a session from ``directory``: newest checkpoint + replay
        of every journaled delta with a later sequence number.

        Bit-identical to the uninterrupted session: the checkpoint restores
        the snapshot mirrors exactly (free-list order included — it steers
        slot placement and therefore floating-point summation order), the
        frontier-caps high-water mark (overflow→dense fallback changes
        summation order too), and the rank vector; the replay then re-runs
        the deterministic per-batch lifecycle. A torn journal tail (crash
        mid-append) is detected by ``DeltaJournal.scan`` and dropped — at
        most the batch being written when the process died.

        ``mesh`` must be re-supplied for sharded sessions (meshes don't
        serialize)."""
        try:
            return cls._restore_impl(directory, mesh)
        except Exception as e:
            # a failed recovery is the post-mortem case par excellence:
            # bundle the flight tail + registry before re-raising (the
            # write is best-effort and never masks the original error)
            write_bundle(directory, reason="restore_failed",
                         extra={"error": repr(e)})
            raise

    @classmethod
    def _restore_impl(cls, directory: str, mesh) -> "StreamSession":
        arrays, extra, step = load_session_checkpoint(directory)
        cfg = extra["session"]
        if cfg["mesh"] and mesh is None:
            raise ValueError("checkpoint is from a mesh session: pass mesh=")
        if not cfg["mesh"] and mesh is not None:
            raise ValueError("checkpoint is single-device: mesh= given")
        params = PRParams(*cfg["params"])
        guard = None
        if cfg.get("guard") is not None:
            gd = dict(cfg["guard"])
            if gd.get("recovery_params") is not None:
                gd["recovery_params"] = PRParams(*gd["recovery_params"])
            guard = GuardConfig(**gd)
        slo = None
        if cfg.get("slo") is not None:
            sd = dict(cfg["slo"])
            if sd.get("solve_p99_us") is None:
                sd["solve_p99_us"] = float("inf")
            slo = SLOConfig(**sd)
        g = graph_from_sorted_keys(
            int(cfg["n"]), np.ascontiguousarray(arrays["keys"]))
        sess = cls(g, params=params, d_p=cfg["d_p"], tile=cfg["tile"],
                   engine=cfg["engine"], prune=cfg["prune"],
                   compact_threshold=cfg["compact_threshold"], mesh=mesh,
                   trace=cfg["trace"], guard=guard, slo=slo,
                   journal_dir=directory,
                   checkpoint_every=cfg["checkpoint_every"],
                   **cfg.get("snap_kw", {}))
        sess.snap.load_state(arrays, extra["snap"])
        sess.ranks = jnp.asarray(arrays["ranks"])
        sess._batch_idx = step
        sess._caps = _caps_from_json(extra.get("frontier_caps"))
        records, _ = DeltaJournal.scan(journal_path(directory))
        sess._replaying = True
        replayed = 0
        try:
            for rec in records:
                if rec.seq <= step:
                    continue
                sess.apply(Delta(
                    n=rec.n, del_src=rec.del_src.astype(np.int64),
                    del_dst=rec.del_dst.astype(np.int64),
                    ins_src=rec.ins_src.astype(np.int64),
                    ins_dst=rec.ins_dst.astype(np.int64)))
                sess._batch_idx = rec.seq
                replayed += 1
        finally:
            sess._replaying = False
        _obs().inc("guard.restores")
        get_flight().emit("guard.restore", step=step, replayed=replayed)
        return sess

    def close(self) -> None:
        """Close the journal file handle (restore() reopens on demand)."""
        if self._journal is not None:
            self._journal.close()
            self._journal = None

    # -- engine/caps plumbing ------------------------------------------------

    def _frontier_caps(self, est: int):
        """Frontier capacity plan for this batch — the running elementwise
        max over the stream (never-shrink), so capacities only grow at a
        new high-water mark and the engine's jit cache survives every batch
        below it. `frontier.caps_growth` counts the (re-tracing) growth
        events."""
        new = (sharded_frontier_caps(self.snap.sg, est)
               if self.mesh is not None else caps_for(self.snap.dg, est))
        merged = merge_caps(self._caps, new)
        if self._caps is not None and merged != self._caps:
            _obs().inc("frontier.caps_growth")
        self._caps = merged
        return merged

    def _choose_engine(self, delta: Delta) -> str:
        if self.mesh is not None:
            return "sharded"
        if self.engine != "auto":
            return self.engine
        return choose_engine(delta, self.snap._outdeg, self.n,
                             self.compact_threshold)

    def _static_solve(self, params: Optional[PRParams] = None,
                      health: bool = False):
        """From-scratch static solve on the current snapshot, in the
        session's native rank layout (dense [n], or stacked [nd, n_loc] in
        mesh mode). The single place the recipe lives: init vector, engine
        choice and params stay in lock-step across __init__ /
        static_reference / recompute / the ladder's recompute rung."""
        params = params if params is not None else self.params
        if self.mesh is None:
            return static_pagerank(self.snap.dg, init_ranks(self.n),
                                   params, health=health)
        r0 = jnp.full((self.snap.nd, self.snap.n_loc), 1.0 / self.n,
                      init_ranks(1).dtype)
        return distributed_static_pagerank(self.mesh, self.snap.sg, r0,
                                           params, health=health)

    def _flatten(self, r: jnp.ndarray) -> jnp.ndarray:
        return r if self.mesh is None else jnp.reshape(r, (-1,))[:self.n]

    def flat_ranks(self) -> jnp.ndarray:
        """Current ranks as a dense [n] vector regardless of session mode."""
        return self._flatten(self.ranks)

    def static_reference(self) -> jnp.ndarray:
        """From-scratch static solve on the *current* snapshot, dense [n] —
        the verification anchor for the chained DF-P ranks. Does not touch
        session state."""
        return self._flatten(self._static_solve()[0])

    def topk(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k vertices by rank: (ids [k], ranks [k]), descending."""
        vals, ids = jax.lax.top_k(self.flat_ranks(), k)
        return np.asarray(ids), np.asarray(vals)

    def recompute(self) -> jnp.ndarray:
        """Full static recomputation on the current snapshot (re-sync /
        verification anchor); resets the session's rank state. Appends an
        ``engine="recompute"`` record to ``history`` and bumps the
        ``session.recompute`` counter, so resyncs are visible in the same
        accounting stream as regular batches."""
        t0 = time.perf_counter()
        self.ranks, iters = self._static_solve()
        _obs().inc("session.recompute")
        self.history.append(BatchStats(
            batch_size=0, engine="recompute", iters=int(iters),
            ingest_s=0.0, snapshot=SnapshotStats(),
            solve_s=time.perf_counter() - t0))
        return self.ranks
