"""Incremental device-resident snapshot maintenance.

``DeviceSnapshot`` owns both hybrid layouts of the current graph G^t —

  * the **pull** half (rows = in-neighbors): rank pull + frontier expansion,
  * the **fwd** half (rows = out-neighbors): compacted frontier scatter —

and applies a canonical ``Delta`` *in place*: O(|Δ| · d_p) host bookkeeping
plus O(touched rows) device scatters, instead of the O(|E|) host rebuild
(`apply_batch` + `build_hybrid`) the static pipeline pays per batch.

Mechanics per edited row (mirrors are host numpy; device arrays are updated
by row/tile scatters, via `kernels.stream_scatter` on TPU):

  * low-degree endpoints: bucketed-ELL slot edits — append at the row's
    fill cursor, delete by swapping the last valid entry into the hole; a
    row that outgrows its bucket's width promotes to the next wider bucket,
    one that shrinks to half the narrower width demotes (per-bucket free
    lists, same swap discipline as the tile pool);
  * high-degree endpoints: tile-slot edits against a **free list** — the
    last tile of a vertex is the only partial one, so inserts append there
    (allocating a fresh tile when it fills) and deletes swap from it
    (freeing it when it empties). Used tiles therefore always equal
    ceil(deg/tile) per vertex — no hole accumulation;
  * degree-crossing vertices migrate between sides: deg > d_p promotes a
    row out of the ELL into tiles; demotion back happens only once deg
    drops to `low_water` (< d_p hysteresis) to avoid thrash, parking some
    sub-d_p vertices on the tile side — the *fragmentation* this design
    tolerates, bounded by `frag_budget`.

Fallback: capacity exhaustion (slot/tile free list empty), fragmentation
above budget, or a batch too large for incremental maintenance to win
(`rebuild_threshold` · |E|) all route to a full vectorized `build_hybrid`
rebuild at fixed capacities (grown by pow2 when genuinely exceeded, which
is the only event that changes device shapes / retriggers jit).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import (Graph, HybridLayout, bucket_band_counts,
                          build_hybrid, choose_bucket_widths, edge_keys,
                          graph_from_sorted_keys, keys_to_edges)
from ..core.pagerank import DeviceGraph, EllBlock
from ..obs.flight import get_flight
from ..obs.spans import get_registry as _obs
from .delta import Delta, next_pow2

__all__ = ["CapacityError", "DeviceSnapshot", "SnapshotStats"]


class CapacityError(RuntimeError):
    """A fixed-capacity structure (hi slots / tile pool) is exhausted."""


@dataclasses.dataclass
class SnapshotStats:
    """Per-apply accounting (replay aggregates these into latency records)."""
    net_ins: int = 0
    net_del: int = 0
    rows_touched: int = 0
    tiles_touched: int = 0
    migrations: int = 0
    rebuilt: bool = False
    rebuild_reason: str = ""
    host_s: float = 0.0
    device_s: float = 0.0


# ---------------------------------------------------------------------------
# Device scatter helpers (shared jit cache across halves and snapshots)
# ---------------------------------------------------------------------------

@jax.jit
def _scatter_pair(idx, mask, rows, new_idx, new_mask):
    return idx.at[rows].set(new_idx), mask.at[rows].set(new_mask)


@jax.jit
def _scatter_1d(dst, idx, vals):
    return dst.at[idx].set(vals)


def _pad_rows(rows: np.ndarray, cap: int) -> np.ndarray:
    out = np.full(cap, rows[0], np.int32)
    out[:rows.size] = rows
    return out


def apply_net_delta(keys: np.ndarray, n: int, delta: Delta,
                    indeg: np.ndarray, outdeg: np.ndarray):
    """Net-effect of a canonical Δ against the sorted edge-key set.

    Shared by DeviceSnapshot and ShardedSnapshot (the membership guard and
    net-vs-raw semantics are subtle enough that one copy must serve both):
    deletions of absent edges and insertions of present edges are no-ops
    (one vectorized searchsorted membership pass each); the key set is
    maintained sorted; `indeg`/`outdeg` are updated IN PLACE.

    Returns (keys', (d_s, d_d), (i_s, i_d)) — the *net* edge arrays.
    """
    dk = edge_keys(n, delta.del_src, delta.del_dst)
    pos = np.searchsorted(keys, dk)
    found = (pos < keys.size)
    found[found] = keys[pos[found]] == dk[found]
    net_del = dk[found]
    ik = edge_keys(n, delta.ins_src, delta.ins_dst)
    pos = np.searchsorted(keys, ik)
    present = (pos < keys.size)
    present[present] = keys[pos[present]] == ik[present]
    net_ins = ik[~present]
    # maintain the sorted key set (O(|E|) memmove, vectorized)
    if net_del.size:
        keys = np.delete(keys, np.searchsorted(keys, net_del))
    if net_ins.size:
        at = np.searchsorted(keys, net_ins)
        keys = np.insert(keys, at, net_ins)
    # degree bookkeeping
    d_s, d_d = keys_to_edges(n, net_del)
    i_s, i_d = keys_to_edges(n, net_ins)
    np.subtract.at(outdeg, d_s, 1)
    np.subtract.at(indeg, d_d, 1)
    np.add.at(outdeg, i_s, 1)
    np.add.at(indeg, i_d, 1)
    return keys, (d_s, d_d), (i_s, i_d)


def rebuild_reason(delta_size: int, m: int, fragmentation: float,
                   threshold: float, budget: float):
    """The shared rebuild-over-incremental decision: a batch above the cost
    crossover or fragmentation over budget. Returns a reason or None."""
    if delta_size > threshold * max(m, 1):
        return "batch_too_large"
    if fragmentation > budget:
        return "fragmentation"
    return None


class _HalfLayout:
    """Host mirror of one orientation's hybrid layout with in-place edits.

    `row_deg[v]` is the number of neighbors in row v (in-degree for the pull
    half, out-degree for the fwd half). The DeviceGraph's `out_deg` field is
    the *opposite* orientation's degree and is owned by the snapshot.

    The low side is the degree-bucketed ELL: each bucket keeps its own
    [cap_b, w_b] idx/mask mirrors, row-id map and free-slot list. A row
    that outgrows its bucket's width migrates to the next wider bucket (or
    to the tile side past d_p); a row that shrinks migrates down only once
    its degree drops to half the *destination* width (bucket hysteresis) —
    or, from the tile side, to `low_water` (the d_p hysteresis).
    """

    def __init__(self, lay, row_deg: np.ndarray,
                 scatter_impl: str = "jnp", stage_device: bool = True):
        n = lay.n
        self.n, self.d_p, self.tile = n, lay.d_p, lay.tile
        self.widths = tuple(lay.widths)
        self.bk_rows = [np.ascontiguousarray(b.rows) for b in lay.buckets]
        self.bk_idx = [np.ascontiguousarray(b.idx) for b in lay.buckets]
        self.bk_mask = [np.ascontiguousarray(b.mask) for b in lay.buckets]
        self.bucket_of = np.ascontiguousarray(lay.bucket_of)
        self.slot_of = np.ascontiguousarray(lay.slot_of)
        self.hi_tiles = np.ascontiguousarray(lay.hi_tiles)
        self.hi_tmask = np.ascontiguousarray(lay.hi_tmask)
        self.hi_rowmap = np.ascontiguousarray(lay.hi_rowmap)
        self.hi_ids = np.ascontiguousarray(lay.hi_ids)
        self.is_low = np.ascontiguousarray(lay.is_low)
        self.row_deg = row_deg.astype(np.int64).copy()
        self.scatter_impl = scatter_impl
        # slot / tile occupancy, reconstructed from the built layout: ELL
        # bucket slots [0, cnt_b), hi slots [0, n_hi) and tiles
        # [0, nt_total) are used contiguously.
        nb = len(self.widths)
        self.free_bslots: List[List[int]] = []
        for bi in range(nb):
            used = np.nonzero(self.bk_rows[bi] < n)[0]
            used_set = set(used.tolist())
            self.free_bslots.append(
                [s for s in range(self.bk_rows[bi].shape[0] - 1, -1, -1)
                 if s not in used_set])
        n_hi_cap = lay.n_hi_cap
        hi = np.nonzero(lay.hi_ids < n)[0]
        self.hi_slot = np.full(n, -1, np.int64)
        self.hi_slot[lay.hi_ids[hi]] = hi
        self.slot_tiles: List[List[int]] = [[] for _ in range(n_hi_cap)]
        used_tiles = np.nonzero(lay.hi_tmask.any(axis=1))[0]
        for t in used_tiles.tolist():
            self.slot_tiles[int(lay.hi_rowmap[t])].append(t)
        used_t = set(used_tiles.tolist())
        self.free_tiles = [t for t in range(lay.hi_tiles.shape[0] - 1, -1, -1)
                           if t not in used_t]
        used_s = set(hi.tolist())
        self.free_slots = [s for s in range(n_hi_cap - 1, -1, -1)
                           if s not in used_s]
        self._dirty_slots: List[set] = [set() for _ in range(nb)]
        self._dirty_tiles: set = set()
        self._bmap_dirty = [False] * nb  # bucket rows map changed (migration)
        self._rowmap_dirty = False   # hi_rowmap changed (tile alloc/free)
        self._side_dirty = False     # hi_ids/is_low/bucket_of/slot_of changed
        self.migrations = 0
        # Device residents. Staged from COPIES: on CPU, jax may zero-copy
        # alias a suitably-aligned numpy buffer, and these mirrors are
        # mutated in place across batches — aliasing would mutate the
        # "immutable" device arrays underneath cached computations.
        # `stage_device=False` skips staging entirely: the sharded snapshot
        # (stream/sharded.py) reuses this host-edit machinery per shard but
        # owns STACKED device arrays itself, draining `drain_dirty()` into
        # per-shard scatters instead of calling `device_refresh`.
        self._staged = stage_device
        if stage_device:
            self._stage_device()

    def _stage_device(self) -> None:
        self.dev_bk_rows = [jnp.asarray(a.copy()) for a in self.bk_rows]
        self.dev_bk_idx = [jnp.asarray(a.copy()) for a in self.bk_idx]
        self.dev_bk_mask = [jnp.asarray(a.copy()) for a in self.bk_mask]
        self.dev_bucket_of = jnp.asarray(self.bucket_of.copy())
        self.dev_slot_of = jnp.asarray(self.slot_of.copy())
        self.dev_hi_tiles = jnp.asarray(self.hi_tiles.copy())
        self.dev_hi_tmask = jnp.asarray(self.hi_tmask.copy())
        self.dev_hi_rowmap = jnp.asarray(self.hi_rowmap.copy())
        self.dev_hi_ids = jnp.asarray(self.hi_ids.copy())
        self.dev_is_low = jnp.asarray(self.is_low.copy())

    # -- checkpoint state (guard.journal) ------------------------------------

    def state_dict(self, prefix: str) -> dict:
        """Complete host-mirror state as a flat {name: np.ndarray} dict.

        Everything that steers future edits is captured, INCLUDING the
        free-list orders: a free list is consumed LIFO, so its order decides
        where the next insertion lands, which decides gather/summation
        order, which decides the floating-point result. Restoring anything
        less than the exact order would be correct-but-not-bit-identical.
        ``slot_tiles`` (ragged per-slot tile lists) flattens to the usual
        offsets+data pair.
        """
        st = {}
        for bi in range(len(self.widths)):
            st[f"{prefix}bk_rows{bi}"] = self.bk_rows[bi]
            st[f"{prefix}bk_idx{bi}"] = self.bk_idx[bi]
            st[f"{prefix}bk_mask{bi}"] = self.bk_mask[bi]
            st[f"{prefix}free_bslots{bi}"] = np.asarray(
                self.free_bslots[bi], np.int64)
        st[f"{prefix}bucket_of"] = self.bucket_of
        st[f"{prefix}slot_of"] = self.slot_of
        st[f"{prefix}hi_tiles"] = self.hi_tiles
        st[f"{prefix}hi_tmask"] = self.hi_tmask
        st[f"{prefix}hi_rowmap"] = self.hi_rowmap
        st[f"{prefix}hi_ids"] = self.hi_ids
        st[f"{prefix}is_low"] = self.is_low
        st[f"{prefix}row_deg"] = self.row_deg
        st[f"{prefix}hi_slot"] = self.hi_slot
        st[f"{prefix}free_tiles"] = np.asarray(self.free_tiles, np.int64)
        st[f"{prefix}free_slots"] = np.asarray(self.free_slots, np.int64)
        off = np.zeros(len(self.slot_tiles) + 1, np.int64)
        off[1:] = np.cumsum([len(t) for t in self.slot_tiles])
        st[f"{prefix}slot_tiles_off"] = off
        st[f"{prefix}slot_tiles_dat"] = np.asarray(
            [t for ts in self.slot_tiles for t in ts], np.int64)
        st[f"{prefix}migrations"] = np.asarray([self.migrations], np.int64)
        return st

    def load_state(self, st: dict, prefix: str) -> None:
        """Inverse of ``state_dict`` — overwrites the mirrors of a half
        built at the SAME capacities, then restages the device arrays."""
        nb = len(self.widths)
        for bi in range(nb):
            self.bk_rows[bi] = np.ascontiguousarray(st[f"{prefix}bk_rows{bi}"])
            self.bk_idx[bi] = np.ascontiguousarray(st[f"{prefix}bk_idx{bi}"])
            self.bk_mask[bi] = np.ascontiguousarray(st[f"{prefix}bk_mask{bi}"])
            self.free_bslots[bi] = [
                int(s) for s in st[f"{prefix}free_bslots{bi}"]]
        for name in ("bucket_of", "slot_of", "hi_tiles", "hi_tmask",
                     "hi_rowmap", "hi_ids", "is_low", "row_deg", "hi_slot"):
            setattr(self, name, np.ascontiguousarray(st[f"{prefix}{name}"]))
        self.free_tiles = [int(t) for t in st[f"{prefix}free_tiles"]]
        self.free_slots = [int(s) for s in st[f"{prefix}free_slots"]]
        off = st[f"{prefix}slot_tiles_off"]
        dat = st[f"{prefix}slot_tiles_dat"]
        self.slot_tiles = [
            [int(t) for t in dat[off[i]:off[i + 1]]]
            for i in range(off.shape[0] - 1)]
        self.migrations = int(st[f"{prefix}migrations"][0])
        self._dirty_slots = [set() for _ in range(nb)]
        self._dirty_tiles = set()
        self._bmap_dirty = [False] * nb
        self._rowmap_dirty = self._side_dirty = False
        if self._staged:
            self._stage_device()

    # -- dirty-state handoff (sharded snapshot path) -------------------------

    def drain_dirty(self):
        """Return and clear the dirty state as a dict:
        `bucket_slots` (list of slot-id arrays per bucket), `bucket_maps`
        (list of bool: bucket rows map changed), `tiles`, `rowmap_dirty`,
        `side_dirty`.

        For owners that stage the device arrays themselves (stacked sharded
        layouts): the host mirrors are current, the returned ids say exactly
        which slots/tiles must be re-scattered.
        """
        nt = len(self._dirty_tiles)
        out = dict(
            bucket_slots=[np.fromiter(s, np.int32, len(s))
                          for s in self._dirty_slots],
            bucket_maps=list(self._bmap_dirty),
            tiles=np.fromiter(self._dirty_tiles, np.int32, nt),
            rowmap_dirty=self._rowmap_dirty,
            side_dirty=self._side_dirty,
        )
        for s in self._dirty_slots:
            s.clear()
        self._dirty_tiles.clear()
        self._bmap_dirty = [False] * len(self.widths)
        self._rowmap_dirty = self._side_dirty = False
        return out

    # -- structural edits (host mirrors) ------------------------------------

    def insert(self, row: int, nbr: int) -> None:
        if self.is_low[row]:
            bi = int(self.bucket_of[row])
            d = int(self.row_deg[row])
            if d >= self.widths[bi]:
                if bi + 1 < len(self.widths):
                    self._migrate_bucket(row, bi, bi + 1)
                    bi += 1
                else:
                    self._migrate_to_high(row)
                    self._hi_insert(row, nbr)
                    return
            slot = int(self.slot_of[row])
            self.bk_idx[bi][slot, d] = nbr
            self.bk_mask[bi][slot, d] = 1.0
            self.row_deg[row] = d + 1
            self._dirty_slots[bi].add(slot)
            return
        self._hi_insert(row, nbr)

    def delete(self, row: int, nbr: int) -> None:
        if self.is_low[row]:
            bi = int(self.bucket_of[row])
            slot = int(self.slot_of[row])
            d = int(self.row_deg[row])
            j = int(np.nonzero(self.bk_idx[bi][slot, :d] == nbr)[0][0])
            last = d - 1
            self.bk_idx[bi][slot, j] = self.bk_idx[bi][slot, last]
            self.bk_idx[bi][slot, last] = 0
            self.bk_mask[bi][slot, last] = 0.0
            self.row_deg[row] = last
            self._dirty_slots[bi].add(slot)
            # demote only once the row would half-fill the narrower bucket
            if bi > 0 and last <= self.widths[bi - 1] // 2:
                self._migrate_bucket(row, bi, bi - 1)
            return
        self._hi_delete(row, nbr)
        if self.widths and self.row_deg[row] <= self.low_water:
            self._migrate_to_low(row)

    @property
    def low_water(self) -> int:
        return getattr(self, "_low_water", max(self.d_p // 2, 1))

    @low_water.setter
    def low_water(self, v: int) -> None:
        self._low_water = min(v, self.d_p)

    # -- ELL bucket slot management -----------------------------------------

    def _bucket_free(self, bi: int, slot: int) -> None:
        self.bk_idx[bi][slot] = 0
        self.bk_mask[bi][slot] = 0.0
        self.bk_rows[bi][slot] = self.n  # sentinel
        self.free_bslots[bi].append(slot)
        self._dirty_slots[bi].add(slot)
        self._bmap_dirty[bi] = True

    def _bucket_place(self, row: int, bi: int, nbrs: np.ndarray) -> None:
        if not self.free_bslots[bi]:
            raise CapacityError(f"bucket {self.widths[bi]} slots exhausted")
        slot = self.free_bslots[bi].pop()
        self.bk_rows[bi][slot] = row
        self.bk_idx[bi][slot, :nbrs.size] = nbrs
        self.bk_mask[bi][slot, :nbrs.size] = 1.0
        self.bucket_of[row] = bi
        self.slot_of[row] = slot
        self._dirty_slots[bi].add(slot)
        self._bmap_dirty[bi] = True
        self._side_dirty = True

    def _migrate_bucket(self, row: int, bi_from: int, bi_to: int) -> None:
        d = int(self.row_deg[row])
        slot = int(self.slot_of[row])
        nbrs = self.bk_idx[bi_from][slot, :d].copy()
        self._bucket_free(bi_from, slot)
        self._bucket_place(row, bi_to, nbrs)
        self.migrations += 1

    def _hi_insert(self, row: int, nbr: int) -> None:
        slot = int(self.hi_slot[row])
        tiles = self.slot_tiles[slot]
        d = int(self.row_deg[row])
        fill = d - (len(tiles) - 1) * self.tile if tiles else self.tile
        if fill == self.tile:
            if not self.free_tiles:
                raise CapacityError("tile pool exhausted")
            t = self.free_tiles.pop()
            self.hi_rowmap[t] = slot
            self._rowmap_dirty = True
            tiles.append(t)
            fill = 0
        t = tiles[-1]
        self.hi_tiles[t, fill] = nbr
        self.hi_tmask[t, fill] = 1.0
        self.row_deg[row] = d + 1
        self._dirty_tiles.add(t)

    def _hi_delete(self, row: int, nbr: int) -> None:
        slot = int(self.hi_slot[row])
        tiles = self.slot_tiles[slot]
        d = int(self.row_deg[row])
        fill = d - (len(tiles) - 1) * self.tile
        t = j = -1
        for cand in tiles:
            hits = np.nonzero((self.hi_tiles[cand] == nbr)
                              & (self.hi_tmask[cand] > 0))[0]
            if hits.size:
                t, j = cand, int(hits[0])
                break
        assert t >= 0, "edge not present in tile list"
        tl, jl = tiles[-1], fill - 1
        self.hi_tiles[t, j] = self.hi_tiles[tl, jl]
        self.hi_tiles[tl, jl] = 0
        self.hi_tmask[tl, jl] = 0.0
        self._dirty_tiles.add(t)
        self._dirty_tiles.add(tl)
        self.row_deg[row] = d - 1
        if jl == 0:  # last tile emptied
            tiles.pop()
            self._free_tile(tl)

    def _free_tile(self, t: int) -> None:
        self.hi_tiles[t] = 0
        self.hi_tmask[t] = 0.0
        self.hi_rowmap[t] = self.hi_ids.shape[0] - 1  # pad convention
        self._rowmap_dirty = True
        self.free_tiles.append(t)
        self._dirty_tiles.add(t)

    def _migrate_to_high(self, row: int) -> None:
        if not self.free_slots:
            raise CapacityError("hi slot table exhausted")
        slot = self.free_slots.pop()
        self.hi_slot[row] = slot
        self.hi_ids[slot] = row
        self._side_dirty = True
        d = int(self.row_deg[row])
        bi = int(self.bucket_of[row])
        bslot = int(self.slot_of[row])
        nbrs = self.bk_idx[bi][bslot, :d].copy()
        self._bucket_free(bi, bslot)
        self.bucket_of[row] = len(self.widths)  # CSR-side sentinel
        self.slot_of[row] = slot
        self.is_low[row] = False
        tiles = self.slot_tiles[slot]
        for off in range(0, d, self.tile):
            if not self.free_tiles:
                raise CapacityError("tile pool exhausted")
            t = self.free_tiles.pop()
            chunk = nbrs[off:off + self.tile]
            self.hi_tiles[t, :chunk.size] = chunk
            self.hi_tmask[t, :chunk.size] = 1.0
            self.hi_rowmap[t] = slot
            self._rowmap_dirty = True
            tiles.append(t)
            self._dirty_tiles.add(t)
        self.migrations += 1

    def _migrate_to_low(self, row: int) -> None:
        slot = int(self.hi_slot[row])
        tiles = self.slot_tiles[slot]
        d = int(self.row_deg[row])
        nbrs = np.zeros(d, np.int32)
        at = 0
        for t in tiles:
            valid = np.nonzero(self.hi_tmask[t] > 0)[0]
            nbrs[at:at + valid.size] = self.hi_tiles[t, valid]
            at += valid.size
        for t in list(tiles):
            self._free_tile(t)
        self.slot_tiles[slot] = []
        self.hi_ids[slot] = self.n  # sentinel
        self._side_dirty = True
        self.free_slots.append(slot)
        self.hi_slot[row] = -1
        # land in the narrowest bucket that fits the current degree — the
        # same placement rule build_hybrid_rows uses
        bi = int(np.searchsorted(np.asarray(self.widths), max(d, 1), "left"))
        self._bucket_place(row, bi, nbrs)
        self.is_low[row] = True
        self.migrations += 1

    # -- fragmentation ------------------------------------------------------

    def tile_waste(self) -> float:
        """Excess tile slots relative to a fresh rebuild, as a fraction of
        allocated slots. Final-tile padding is charged to both sides (a
        rebuild pays it too), so what remains is exactly the tiles held by
        sub-d_p vertices parked on the high side by the demotion hysteresis
        — the one fragmentation source this design tolerates."""
        used = self.hi_tiles.shape[0] - len(self.free_tiles)
        if used == 0:
            return 0.0
        deg = self.row_deg[~self.is_low]
        ideal = int(((deg[deg > self.d_p] + self.tile - 1)
                     // self.tile).sum())
        return (used - ideal) / float(used)

    # -- device refresh -----------------------------------------------------

    def _scatter(self, dev_idx, dev_mask, host_idx, host_mask, ids):
        rows = _pad_rows(ids, next_pow2(ids.size))
        new_i = jnp.asarray(host_idx[rows])
        new_m = jnp.asarray(host_mask[rows])
        rows = jnp.asarray(rows)
        if self.scatter_impl == "pallas":
            from ..kernels.stream_scatter import ell_scatter_rows
            return ell_scatter_rows(dev_idx, dev_mask, rows, new_i, new_m)
        return _scatter_pair(dev_idx, dev_mask, rows, new_i, new_m)

    def device_refresh(self) -> tuple:
        """Push dirty slots/tiles to the device arrays; returns (#slots, #tiles)."""
        nr = sum(len(s) for s in self._dirty_slots)
        nt = len(self._dirty_tiles)
        for bi, dirty in enumerate(self._dirty_slots):
            if dirty:
                ids = np.fromiter(dirty, np.int32, len(dirty))
                self.dev_bk_idx[bi], self.dev_bk_mask[bi] = self._scatter(
                    self.dev_bk_idx[bi], self.dev_bk_mask[bi],
                    self.bk_idx[bi], self.bk_mask[bi], ids)
            if self._bmap_dirty[bi]:
                self.dev_bk_rows[bi] = jnp.asarray(self.bk_rows[bi].copy())
        if nt:
            ids = np.fromiter(self._dirty_tiles, np.int32, nt)
            self.dev_hi_tiles, self.dev_hi_tmask = self._scatter(
                self.dev_hi_tiles, self.dev_hi_tmask,
                self.hi_tiles, self.hi_tmask, ids)
        # small 1-D side tables: re-staged wholesale, but only when touched
        # (.copy(): see the aliasing note in __init__)
        if self._rowmap_dirty:
            self.dev_hi_rowmap = jnp.asarray(self.hi_rowmap.copy())
            self._rowmap_dirty = False
        if self._side_dirty:
            self.dev_hi_ids = jnp.asarray(self.hi_ids.copy())
            self.dev_is_low = jnp.asarray(self.is_low.copy())
            self.dev_bucket_of = jnp.asarray(self.bucket_of.copy())
            self.dev_slot_of = jnp.asarray(self.slot_of.copy())
            self._side_dirty = False
        for s in self._dirty_slots:
            s.clear()
        self._dirty_tiles.clear()
        self._bmap_dirty = [False] * len(self.widths)
        return nr, nt

    def device_graph(self, out_deg: jnp.ndarray) -> DeviceGraph:
        buckets = tuple(
            EllBlock(rows=self.dev_bk_rows[bi], idx=self.dev_bk_idx[bi],
                     mask=self.dev_bk_mask[bi])
            for bi in range(len(self.widths)))
        return DeviceGraph(
            buckets=buckets, bucket_of=self.dev_bucket_of,
            slot_of=self.dev_slot_of,
            hi_ids=self.dev_hi_ids, hi_tiles=self.dev_hi_tiles,
            hi_tmask=self.dev_hi_tmask, hi_rowmap=self.dev_hi_rowmap,
            is_low=self.dev_is_low, out_deg=out_deg)


class DeviceSnapshot:
    """Both hybrid layouts of G^t, maintained incrementally across batches.

    Exposes `.dg` (pull orientation) and `.fwd_dg` (forward orientation) —
    the pre-staged snapshot interface every core driver accepts directly.
    """

    def __init__(self, g: Graph, d_p: int = 64, tile: int = 256,
                 hi_headroom: float = 2.0, tile_headroom: float = 2.0,
                 rebuild_threshold: float = 0.05, frag_budget: float = 0.6,
                 low_water: Optional[int] = None, scatter_impl: str = "jnp"):
        self.n = g.n
        self.d_p, self.tile = d_p, tile
        self.rebuild_threshold = rebuild_threshold
        self.frag_budget = frag_budget
        self._low_water = low_water
        self._scatter_impl = scatter_impl
        self._hi_headroom, self._tile_headroom = hi_headroom, tile_headroom
        src, dst = g.edges()
        self._keys = np.sort(edge_keys(g.n, src, dst))
        self._indeg = g.in_degree().astype(np.int64)
        self._outdeg = g.out_degree().astype(np.int64)
        self._adopt(g)

    # -- construction / rebuild ---------------------------------------------

    def _caps_for(self, indeg: np.ndarray, outdeg: np.ndarray,
                  widths: Optional[tuple] = None) -> dict:
        # widths are chosen ONCE from both orientations' histograms and then
        # frozen across rebuilds (passed back in): only bucket_caps may grow,
        # so device shapes stay stable modulo genuine capacity growth.
        if widths is None:
            widths = choose_bucket_widths(
                np.concatenate([indeg, outdeg]), self.d_p)

        def side(deg):
            hi = deg[deg > self.d_p]
            n_hi = int(hi.size)
            nt = int(((hi + self.tile - 1) // self.tile).sum())
            # bucket caps must cover the hysteresis *band*, not just the
            # initial placement census — see bucket_band_counts
            nb = bucket_band_counts(deg, widths, self.d_p)
            return n_hi, nt, nb

        hi_p, nt_p, nb_p = side(indeg)
        hi_f, nt_f, nb_f = side(outdeg)
        n_hi_cap = next_pow2(int(max(hi_p, hi_f, 1) * self._hi_headroom), 8)
        t_cap = next_pow2(int(max(nt_p, nt_f, 1) * self._tile_headroom), 8)
        bucket_caps = tuple(
            next_pow2(int(max(int(p), int(f), 1) * self._hi_headroom), 8)
            for p, f in zip(nb_p, nb_f))
        return dict(n_hi_cap=n_hi_cap, t_cap=t_cap,
                    widths=tuple(widths), bucket_caps=bucket_caps)

    def _adopt(self, g: Graph, caps: Optional[dict] = None) -> None:
        """(Re)build both halves from a host Graph at fixed capacities."""
        caps = caps or self._caps_for(self._indeg, self._outdeg)
        lay_p = build_hybrid(g, d_p=self.d_p, tile=self.tile, **caps)
        lay_f = build_hybrid(g.transpose(), d_p=self.d_p, tile=self.tile,
                             **caps)
        self._caps = caps
        self._pull = _HalfLayout(lay_p, self._indeg, self._scatter_impl)
        self._fwd = _HalfLayout(lay_f, self._outdeg, self._scatter_impl)
        if self._low_water is not None:
            self._pull.low_water = self._low_water
            self._fwd.low_water = self._low_water
        self._dev_outdeg = jnp.asarray(self._outdeg.astype(np.int32))
        self._dev_indeg = jnp.asarray(self._indeg.astype(np.int32))

    def _rebuild(self, reason: str) -> None:
        g = self.graph()
        caps = self._caps_for(self._indeg, self._outdeg,
                              widths=self._caps["widths"])
        # never shrink: keep device shapes stable unless we *must* grow
        # (widths stay frozen; bucket_caps grow elementwise)
        caps = dict(
            n_hi_cap=max(caps["n_hi_cap"], self._caps["n_hi_cap"]),
            t_cap=max(caps["t_cap"], self._caps["t_cap"]),
            widths=self._caps["widths"],
            bucket_caps=tuple(max(a, b) for a, b in
                              zip(caps["bucket_caps"],
                                  self._caps["bucket_caps"])),
        )
        self._adopt(g, caps)
        self._last_rebuild_reason = reason

    # -- queries -------------------------------------------------------------

    @property
    def m(self) -> int:
        return int(self._keys.size)

    @property
    def dg(self) -> DeviceGraph:
        return self._pull.device_graph(self._dev_outdeg)

    @property
    def fwd_dg(self) -> DeviceGraph:
        return self._fwd.device_graph(self._dev_indeg)

    def graph(self) -> Graph:
        """Materialize the host CSR Graph (verification / rebuild path)."""
        return graph_from_sorted_keys(self.n, self._keys)

    def fragmentation(self) -> float:
        return max(self._pull.tile_waste(), self._fwd.tile_waste())

    # -- checkpoint state (guard.journal) ------------------------------------

    def state_dict(self) -> tuple:
        """(arrays, extra): the complete snapshot state for a bit-identical
        session checkpoint. ``arrays`` is a flat {name: np.ndarray} dict
        (edge keys, degrees, both halves' mirrors + free-list orders);
        ``extra`` is the JSON-safe capacity signature ``load_state`` rebuilds
        at (shapes must match for the mirror overwrite)."""
        arrays = dict(keys=self._keys, indeg=self._indeg,
                      outdeg=self._outdeg)
        arrays.update(self._pull.state_dict("p."))
        arrays.update(self._fwd.state_dict("f."))
        extra = {"caps": {k: list(v) if isinstance(v, tuple) else int(v)
                          for k, v in self._caps.items()}}
        return arrays, extra

    def load_state(self, arrays: dict, extra: dict) -> None:
        """Restore from ``state_dict`` output: re-adopt at the checkpointed
        capacities (device shapes match), then overwrite every mirror."""
        self._keys = np.ascontiguousarray(arrays["keys"])
        self._indeg = np.ascontiguousarray(arrays["indeg"])
        self._outdeg = np.ascontiguousarray(arrays["outdeg"])
        caps = {k: tuple(v) if isinstance(v, list) else int(v)
                for k, v in extra["caps"].items()}
        self._adopt(self.graph(), caps)
        self._pull.load_state(arrays, "p.")
        self._fwd.load_state(arrays, "f.")

    # -- the batch-update lifecycle ------------------------------------------

    def apply(self, delta: Delta) -> SnapshotStats:
        """Apply a canonical Δ^t in place; returns per-apply stats.

        Every apply also feeds the process-wide obs registry: spans for the
        host-edit and device-refresh phases, counters for the in-place vs
        rebuild decision, scatter traffic and degree-crossing migrations
        (span/counter names: DESIGN.md §10)."""
        obs = _obs()
        t0 = time.perf_counter()
        stats = SnapshotStats()
        with obs.span("snapshot.apply_net_delta"):
            self._keys, (d_s, d_d), (i_s, i_d) = apply_net_delta(
                self._keys, self.n, delta, self._indeg, self._outdeg)
        stats.net_del, stats.net_ins = int(d_s.size), int(i_s.size)

        reason = rebuild_reason(delta.size, self.m, self.fragmentation(),
                                self.rebuild_threshold, self.frag_budget)
        if reason is not None:
            with obs.span("snapshot.rebuild"):
                self._rebuild(reason)
            obs.inc("snapshot.rebuilds")
            obs.inc(f"snapshot.rebuild.{reason.split(':')[0]}")
            get_flight().emit("snapshot.rebuild", reason=reason)
            stats.rebuilt, stats.rebuild_reason = True, reason
            stats.host_s = time.perf_counter() - t0
            return stats

        mig0 = self._pull.migrations + self._fwd.migrations
        try:
            with obs.span("snapshot.host_edit"):
                for u, v in zip(d_s.tolist(), d_d.tolist()):
                    self._pull.delete(v, u)
                    self._fwd.delete(u, v)
                for u, v in zip(i_s.tolist(), i_d.tolist()):
                    self._pull.insert(v, u)
                    self._fwd.insert(u, v)
        except CapacityError as e:
            # mirrors are mid-edit but the key set is complete: rebuild from it
            with obs.span("snapshot.rebuild"):
                self._rebuild(f"capacity:{e}")
            obs.inc("snapshot.rebuilds")
            obs.inc("snapshot.rebuild.capacity")
            get_flight().emit("snapshot.rebuild", reason=f"capacity:{e}")
            stats.rebuilt, stats.rebuild_reason = True, f"capacity:{e}"
            stats.host_s = time.perf_counter() - t0
            return stats

        stats.migrations = self._pull.migrations + self._fwd.migrations - mig0
        stats.host_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        with obs.span("snapshot.device_refresh", annotate=True):
            rows_p, tiles_p = self._pull.device_refresh()
            rows_f, tiles_f = self._fwd.device_refresh()
            touched = np.unique(np.concatenate([d_s, d_d, i_s, i_d]))
            if touched.size:
                at = _pad_rows(touched.astype(np.int32),
                               next_pow2(touched.size))
                ja = jnp.asarray(at)
                self._dev_outdeg = _scatter_1d(
                    self._dev_outdeg, ja,
                    jnp.asarray(self._outdeg[at].astype(np.int32)))
                self._dev_indeg = _scatter_1d(
                    self._dev_indeg, ja,
                    jnp.asarray(self._indeg[at].astype(np.int32)))
        stats.rows_touched = rows_p + rows_f
        stats.tiles_touched = tiles_p + tiles_f
        stats.device_s = time.perf_counter() - t1
        obs.inc("snapshot.inplace_batches")
        obs.inc("snapshot.rows_touched", stats.rows_touched)
        obs.inc("snapshot.tiles_touched", stats.tiles_touched)
        obs.inc("snapshot.migrations", stats.migrations)
        return stats
