"""Temporal-stream replayer: drive workloads through a StreamSession.

Feeds `temporal_stream` / `random_batch` workloads batch-by-batch through a
session, recording per-batch latency split into the lifecycle stages
(ingest / snapshot host / snapshot device / DF-P solve) plus optional
ground-truth error against a from-scratch static recompute — the paper's
§5.1.4 measurement protocol as a reusable harness.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional

import numpy as np

from ..core.graph import BatchUpdate, Graph, random_batch
from ..core.reference import l1_error
from .session import BatchStats, StreamSession

__all__ = ["ReplayRecord", "replay", "churn_workload"]


@dataclasses.dataclass
class ReplayRecord:
    """One batch of the replay: latency breakdown + optional L1 error."""
    t: int
    stats: BatchStats
    l1_vs_static: Optional[float] = None

    @property
    def total_s(self) -> float:
        return self.stats.total_s


def replay(session: StreamSession, batches: Iterable[BatchUpdate],
           verify_every: int = 0,
           on_batch: Optional[Callable[[ReplayRecord], None]] = None
           ) -> List[ReplayRecord]:
    """Apply `batches` in order; every `verify_every`-th batch (0 = never)
    also recomputes static PageRank from scratch on the maintained snapshot
    and records the L1 gap — the acceptance metric for incremental
    maintenance (ranks must track the from-scratch answer)."""
    records: List[ReplayRecord] = []
    for t, b in enumerate(batches):
        session.apply(b)
        err = None
        if verify_every and (t + 1) % verify_every == 0:
            # session-mode-agnostic: flat_ranks/static_reference cover both
            # the single-device and the sharded (mesh=) sessions
            err = l1_error(np.asarray(session.flat_ranks()),
                           np.asarray(session.static_reference()))
        rec = ReplayRecord(t=t, stats=session.history[-1], l1_vs_static=err)
        records.append(rec)
        if on_batch is not None:
            on_batch(rec)
    return records


def churn_workload(g: Graph, frac: float, n_batches: int,
                   insert_frac: float = 0.8, seed: int = 0
                   ) -> List[BatchUpdate]:
    """Uniformly-random churn batches (80/20 insert/delete, paper §5.1.4)
    against a fixed base graph — exercises deletions and degree crossings."""
    return [random_batch(g, frac, insert_frac=insert_frac, seed=seed + t)
            for t in range(n_batches)]
