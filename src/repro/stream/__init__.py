"""repro.stream — incremental snapshot maintenance + streaming DF-P engine.

The batch-update lifecycle as a subsystem: `delta` canonicalizes Δ^t,
`snapshot` maintains both device-resident hybrid layouts in place,
`session` chains DF-P across batches, `replay` drives workloads with
per-batch latency accounting. See DESIGN.md §3.
"""
from .delta import Delta, ingest, next_pow2
from .snapshot import CapacityError, DeviceSnapshot, SnapshotStats
from .sharded import ShardedSnapshot
from .session import BatchStats, StreamSession
from .replay import ReplayRecord, replay, churn_workload

__all__ = [
    "Delta", "ingest", "next_pow2",
    "CapacityError", "DeviceSnapshot", "SnapshotStats", "ShardedSnapshot",
    "BatchStats", "StreamSession",
    "ReplayRecord", "replay", "churn_workload",
]
