"""repro: TPU-native Static & DF-P PageRank framework (Sahu 2024) +
multi-arch LM substrate sharing the same distributed runtime."""
__version__ = "1.0.0"
