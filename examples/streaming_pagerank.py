"""Quickstart: streaming DF-P PageRank with `repro.stream.StreamSession`.

Loads 90% of a synthetic temporal edge stream as the base graph (paper
§5.1.4), then feeds the remaining edges through a session batch by batch.
Every batch keeps ranks, frontier state, and both hybrid graph layouts
device-resident; snapshot maintenance is O(|Δ|), not O(|E|).

Run:  PYTHONPATH=src python examples/streaming_pagerank.py
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import temporal_stream
from repro.stream import StreamSession, replay

N, EDGES, BATCHES = 5_000, 80_000, 12


def main():
    base, batches = temporal_stream(N, EDGES, n_batches=BATCHES, seed=0)
    print(f"base graph: {base.n} vertices, {base.m} edges; "
          f"{len(batches)} insertion batches incoming")

    sess = StreamSession(base, d_p=64, tile=256)
    print(f"warm start: static PageRank converged in "
          f"{int(sess._init_iters)} iterations")

    records = replay(sess, batches, verify_every=4)
    for rec in records:
        h = rec.stats
        err = ("" if rec.l1_vs_static is None
               else f"  L1 vs from-scratch: {rec.l1_vs_static:.2e}")
        print(f"batch {rec.t:2d}: |Δ|={h.batch_size:5d}  engine={h.engine:7s}"
              f"  iters={h.iters:3d}  maintain="
              f"{(h.ingest_s + h.snapshot.host_s + h.snapshot.device_s) * 1e3:6.1f}ms"
              f"  solve={h.solve_s * 1e3:6.1f}ms{err}")

    ids, vals = sess.topk(5)
    print("\ntop-5 vertices by rank:")
    for i, v in zip(ids, vals):
        print(f"  vertex {i:5d}  rank {v:.6f}")


if __name__ == "__main__":
    main()
