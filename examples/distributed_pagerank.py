"""Distributed PageRank on 8 (forced) host devices: 1-D vertex partition vs
the beyond-paper 2-D edge partition, both validated against the oracle —
plus a sharded StreamSession chaining DF-P over a live update stream
(mirrors examples/streaming_pagerank.py at multi-device scale).

  PYTHONPATH=src python examples/distributed_pagerank.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import l1_error, powerlaw_graph, reference_pagerank, temporal_stream
from repro.core.distributed import (build_sharded, distributed_static_pagerank,
                                    sharded_caps, unshard_vector)
from repro.core.distributed2d import build_sharded_2d, pagerank_2d
from repro.stream import StreamSession, replay

g = powerlaw_graph(2_000, 30_000, seed=1)
ref = reference_pagerank(g)

# 1-D: vertices over all 8 devices; per-iteration all-gather of c (V floats).
# Every shard block is laid out by the same `build_hybrid_rows` primitive as
# the single-device hybrid, and the loop runs the same `rank_step` math.
mesh = jax.make_mesh((4, 2), ("data", "model"))
sg = build_sharded(g, 8, d_p=16, tile=64)
r0 = jnp.full((8, sg.n_loc), 1.0 / g.n, jnp.float64)
r1, it1 = distributed_static_pagerank(mesh, sg, r0)
print(f"1-D: {int(it1)} iters, caps={sharded_caps(sg)}, L1 vs oracle = "
      f"{l1_error(unshard_vector(r1, g.n), ref):.2e}")

# 2-D: edge blocks on a 2x2 sub-mesh; per-iteration gather is V/2 per device
mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
sg2 = build_sharded_2d(g, 2, 2, d_p=8)
rc, blk = sg2.out_deg.shape
r0b = jnp.full((rc, blk), 1.0 / g.n, jnp.float64)
r2, it2 = pagerank_2d(mesh2, sg2, r0b)
print(f"2-D: {int(it2)} iters, L1 vs oracle = "
      f"{l1_error(np.asarray(r2).reshape(-1)[:g.n], ref):.2e}")

# --- sharded streaming: chained multi-device DF-P over an update stream ---
# The session shards the snapshot over the mesh, maintains every shard's
# hybrid layout in place (touched rows only — no O(|E|) re-partition), and
# seeds each batch's frontier device-side.
base, batches = temporal_stream(4_000, 60_000, n_batches=6, seed=0)
sess = StreamSession(base, mesh=mesh, d_p=16, tile=64)
print(f"\nsharded stream: base {base.n} vertices / {base.m} edges over "
      f"{sess.snap.nd} shards (n_loc={sess.snap.n_loc}); warm start "
      f"{int(sess._init_iters)} iters")
for rec in replay(sess, batches, verify_every=2):
    h = rec.stats
    err = ("" if rec.l1_vs_static is None
           else f"  L1 vs from-scratch: {rec.l1_vs_static:.2e}")
    print(f"batch {rec.t}: |Δ|={h.batch_size:5d}  engine={h.engine}"
          f"  iters={h.iters:3d}  rows_touched={h.snapshot.rows_touched:4d}"
          f"  rebuilt={h.snapshot.rebuilt}{err}")

ids, vals = sess.topk(5)
print("\ntop-5 vertices by rank:")
for i, v in zip(ids, vals):
    print(f"  vertex {i:5d}  rank {v:.6f}")
