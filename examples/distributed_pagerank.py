"""Distributed PageRank on 8 (forced) host devices: 1-D vertex partition vs
the beyond-paper 2-D edge partition, both validated against the oracle.

  PYTHONPATH=src python examples/distributed_pagerank.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import l1_error, powerlaw_graph, reference_pagerank
from repro.core.distributed import build_sharded, distributed_static_pagerank
from repro.core.distributed2d import build_sharded_2d, pagerank_2d

g = powerlaw_graph(2_000, 30_000, seed=1)
ref = reference_pagerank(g)

# 1-D: vertices over all 8 devices; per-iteration all-gather of c (V floats)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sg = build_sharded(g, 8, d_p=16, tile=64)
r0 = jnp.full((8, sg.n_loc), 1.0 / g.n, jnp.float64)
r1, it1 = distributed_static_pagerank(mesh, sg, r0)
print(f"1-D: {int(it1)} iters, L1 vs oracle = "
      f"{l1_error(np.asarray(r1).reshape(-1)[:g.n], ref):.2e}")

# 2-D: edge blocks on a 2x2 sub-mesh; per-iteration gather is V/2 per device
mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
sg2 = build_sharded_2d(g, 2, 2, d_p=8)
rc, blk = sg2.out_deg.shape
r0b = jnp.full((rc, blk), 1.0 / g.n, jnp.float64)
r2, it2 = pagerank_2d(mesh2, sg2, r0b)
print(f"2-D: {int(it2)} iters, L1 vs oracle = "
      f"{l1_error(np.asarray(r2).reshape(-1)[:g.n], ref):.2e}")
