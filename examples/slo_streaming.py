"""SLO-guarded streaming (repro.obs v2): flight recorder, latency
percentiles, breach-armed profiler capture, and a post-mortem bundle.

A production stream session is judged on its tail, not its mean: this demo
runs a guarded StreamSession under an intentionally-unmeetable p99 budget
so every piece of the observability layer fires on a healthy host —

  1. per-batch solve latency lands in the session's histogram (p50/p95/p99);
  2. the running p99 breaches the SLO -> ``slo.breach.solve_p99`` counts,
     a flight event records it, and ``jax.profiler`` capture is armed
     around the next batches (the ``solve.*``/``session.solve`` spans are
     annotated, so kernels show up on that timeline);
  3. a chaos-poisoned batch exhausts the escalation ladder -> a post-mortem
     bundle is written and rendered.

  PYTHONPATH=src python examples/slo_streaming.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import shutil
import tempfile

from repro.core import temporal_stream
from repro.guard import ChaosMonkey, GuardConfig
from repro.obs import SLOConfig, get_flight
from repro.obs.postmortem import render
from repro.obs.spans import get_registry
from repro.stream import StreamSession

workdir = tempfile.mkdtemp(prefix="slo_streaming_")
base, batches = temporal_stream(1_000, 12_000, n_batches=8, seed=11)

# p99 budget of 1µs: unmeetable by construction, so the breach machinery
# demonstrably fires; real deployments set this from their latency target.
slo = SLOConfig(solve_p99_us=1.0, min_samples=4, capture_batches=1,
                capture_dir=f"{workdir}/profile")
sess = StreamSession(base, guard=GuardConfig(
    policy="quarantine", retry_budget=0, postmortem_dir=workdir), slo=slo)

for i, b in enumerate(batches):
    if i == len(batches) - 1:
        # last batch: chaos-poison the rank state; retry_budget=0 means the
        # ladder exhausts immediately and the post-mortem path runs
        sess.ranks = ChaosMonkey(seed=3).poison_ranks(
            sess.ranks, mode="nan", k=1, idx=[5])
    sess.apply(b)

pct = sess.solve_percentiles()
print(f"solve latency over {pct['count']} batches: "
      f"p50={pct['p50_s'] * 1e3:.1f}ms p95={pct['p95_s'] * 1e3:.1f}ms "
      f"p99={pct['p99_s'] * 1e3:.1f}ms")
obs = get_registry()
print(f"SLO breaches: {obs.counter('slo.breach.solve_p99')} "
      f"(captures started: {obs.counter('slo.capture.start')}, "
      f"profiler unavailable: {obs.counter('slo.capture.unavailable')})")
print(f"flight recorder: {get_flight().summary()['total']} events; last 5:")
for e in get_flight().tail(5):
    print(f"  [{e.seq}] {e.kind} {e.data}")

print("\npost-mortem bundle (escalation exhausted on the poisoned batch):")
render(workdir)

shutil.rmtree(workdir, ignore_errors=True)
