"""Fault-tolerant streaming DF-P: quarantine, watchdog, crash recovery.

A `StreamSession` with a `GuardConfig` survives every fault class the
guard layer names (DESIGN.md §13). This demo injects three of them with
the same seeded `ChaosMonkey` the test suite uses:

  1. a batch carrying out-of-range vertex ids (would silently alias
     other edges' keys) — quarantined, the clean remainder streams on;
  2. NaN-poisoned ranks — the device-side health word trips after one
     sweep and the escalation ladder recovers (full-budget retry, then
     static recompute);
  3. a process "crash" — the session is rebuilt bit-identically from its
     newest checkpoint plus a write-ahead journal replay.

Run:  PYTHONPATH=src python examples/fault_tolerant_stream.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import shutil
import tempfile

import numpy as np

from repro.core import l1_error, temporal_stream
from repro.guard import ChaosMonkey, GuardConfig, describe_health
from repro.obs.spans import get_registry
from repro.stream import StreamSession

N, EDGES, BATCHES = 5_000, 80_000, 8


def main():
    base, batches = temporal_stream(N, EDGES, n_batches=BATCHES, seed=0)
    chaos = ChaosMonkey(seed=42)
    jdir = tempfile.mkdtemp(prefix="guarded_stream_")
    sess = StreamSession(base, d_p=64, tile=256,
                         guard=GuardConfig(policy="quarantine"),
                         journal_dir=jdir, checkpoint_every=3)

    # -- 1. malformed input: quarantine instead of corruption ---------------
    bad = chaos.corrupt_batch(batches[0], sess.n, mode="out_of_range", k=3)
    sess.apply(bad)
    st = sess.history[-1]
    print(f"batch 1: engine={st.engine}  quarantined={st.quarantined} "
          f"out-of-range pairs, clean remainder applied")

    # -- 2. numerical poison: watchdog + escalation ladder ------------------
    sess.ranks = chaos.poison_ranks(sess.ranks, mode="nan", k=1, idx=[13])
    sess.apply(batches[1])
    st = sess.history[-1]
    print(f"batch 2: health={describe_health(st.health)}  "
          f"ladder walked {st.escalations} rung(s)  "
          f"L1 vs from-scratch: "
          f"{l1_error(np.asarray(sess.flat_ranks()), np.asarray(sess.static_reference())):.2e}")

    # -- healthy stream continues (journal + periodic checkpoints) ----------
    for b in batches[2:6]:
        sess.apply(b)
    print(f"batches 3-6: healthy "
          f"(health={[st.health for st in sess.history[-4:]]}), "
          f"checkpointed through batch {sess._batch_idx}")
    ranks_before = np.asarray(sess.ranks)
    sess.close()  # "crash": the process goes away here

    # -- 3. kill-and-restore: bit-identical replay --------------------------
    restored = StreamSession.restore(jdir)
    identical = np.array_equal(ranks_before, np.asarray(restored.ranks))
    print(f"restore: replayed to batch {restored._batch_idx}, "
          f"ranks bit-identical: {identical}")

    # the restored session keeps streaming as if nothing happened
    for b in batches[6:]:
        restored.apply(b)
    print(f"post-restore stream: L1 vs from-scratch "
          f"{l1_error(np.asarray(restored.flat_ranks()), np.asarray(restored.static_reference())):.2e}")

    counters = get_registry().report()["counters"]
    print("\nguard counters:")
    for k, v in counters.items():
        if k.startswith("guard."):
            print(f"  {k:32s} {v}")
    restored.close()
    shutil.rmtree(jdir)


if __name__ == "__main__":
    main()
