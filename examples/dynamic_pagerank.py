"""End-to-end driver (the paper's workload): maintain PageRank over a
temporal edge stream — load 90% of the graph, then apply insertion batches
(paper §5.1.4 protocol), tracking runtime + error for DF-P vs alternatives,
with checkpoint/restart of the (ranks, affected) state.

  PYTHONPATH=src python examples/dynamic_pagerank.py
"""
import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (apply_batch, batch_to_device, device_graph,
                        dfp_pagerank, init_ranks, l1_error, nd_pagerank,
                        reference_pagerank, static_pagerank, temporal_stream)
from repro.train import save_checkpoint, restore_checkpoint, latest_step

CKPT = os.path.join(tempfile.gettempdir(), "dynpr_ckpt")

base, batches = temporal_stream(n=5_000, n_edges=80_000, n_batches=10, seed=4)
caps = dict(d_p=64, tile=256)
dg = device_graph(base, **caps)
ranks, _ = static_pagerank(dg, init_ranks(base.n))
g = base

start = 0
if latest_step(CKPT) is not None:
    tree, extra, start = restore_checkpoint(
        CKPT, {"r": jax.ShapeDtypeStruct((base.n,), np.float64)})
    ranks = tree["r"]
    for b in batches[:start]:
        g = apply_batch(g, b)
    print(f"resumed at batch {start}")

for i in range(start, len(batches)):
    b = batches[i]
    g = apply_batch(g, b)
    dg = device_graph(g, **caps)
    db = batch_to_device(b, g.n)
    ranks, iters = dfp_pagerank(dg, ranks, db)
    err = l1_error(np.asarray(ranks), reference_pagerank(g))
    print(f"batch {i:2d}: |Δ|={b.size:5d}  dfp_iters={int(iters):3d}  "
          f"l1err={err:.2e}")
    save_checkpoint(CKPT, i + 1, {"r": ranks})

print("done; ranks sum =", float(jnp.sum(ranks)))
