"""Serve a small model with batched requests: prefill + greedy decode.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.configs import get_config, smoke_config
from repro.launch.serve import serve

cfg = smoke_config(get_config("qwen2-1.5b"))
tokens, tps = serve(cfg, batch=4, prompt_len=24, gen=12)
print(f"batch=4 prompt=24 gen=12 -> {tps:.1f} tok/s")
print("first generations:", tokens[:, :8].tolist())
