"""Train a ~100M-param LM for a few hundred steps on CPU with checkpointing.

Uses the smollm-360m *architecture* at reduced width (smoke config ~ a few M
params for CPU speed; pass --full-width for the real 360M config if you have
the patience / a TPU).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import os
import tempfile

from repro.configs import get_config, smoke_config
from repro.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full-width", action="store_true")
args = ap.parse_args()

cfg = get_config("smollm-360m")
if not args.full_width:
    cfg = smoke_config(cfg)
ckpt = os.path.join(tempfile.gettempdir(), "train_lm_ckpt")
params, history = train(cfg, steps=args.steps, batch=4, seq=128,
                        ckpt_dir=ckpt, ckpt_every=100, log_every=20)
first, last = history[0], history[-1]
print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} over "
      f"{last['step']} steps ({last['sec']:.0f}s)")
assert last["loss"] < first["loss"], "loss should decrease"
