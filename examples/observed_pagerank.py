"""Observability tour: iteration traces + spans/counters on a DF-P stream.

Runs a streaming DF-P session with ``trace=True`` so every per-batch solve
carries its iteration-level telemetry out of the jitted while_loop
(`repro.obs.trace`, DESIGN.md §10), then renders

  * the per-batch frontier-decay table — the paper's Fig. 3 story, read
    straight off `BatchStats.trace["frontier"]`: DF-P touches a shrinking
    affected set each iteration while Static sweeps all |V| every time;
  * the host span/counter registry — where each batch's wall-clock went
    (ingest / snapshot maintenance / solve) and what the snapshot did
    (in-place batches vs rebuilds, rows scattered, migrations).

Tracing is telemetry-neutral: the same session with ``trace=False``
produces bit-identical ranks (tested in tests/test_obs.py).

Run:  PYTHONPATH=src python examples/observed_pagerank.py
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import temporal_stream
from repro.obs import get_registry, reset_registry
from repro.stream import StreamSession

N, EDGES, BATCHES = 5_000, 80_000, 8


def sparkline(series, width=32):
    """Frontier series -> a coarse text profile (max-normalized)."""
    if not series:
        return ""
    blocks = " .:-=+*#%@"
    peak = max(max(series), 1)
    take = series[:width]
    return "".join(blocks[min(int(v / peak * (len(blocks) - 1)),
                              len(blocks) - 1)] for v in take)


def main():
    base, batches = temporal_stream(N, EDGES, n_batches=BATCHES, seed=0)
    print(f"base graph: {base.n} vertices, {base.m} edges; "
          f"{len(batches)} insertion batches incoming\n")

    reset_registry()
    sess = StreamSession(base, d_p=64, tile=256, trace=True)

    print("per-batch frontier decay (|affected| per DF-P iteration):")
    print(f"{'batch':>5} {'engine':>8} {'iters':>5} {'peak':>6} "
          f"{'final':>6} {'pruned':>7}  frontier profile")
    for t, b in enumerate(batches):
        sess.apply(b)
        st = sess.history[-1]
        tr = st.trace
        pruned = sum(p for p in tr["pruned"] if p and p > 0)
        print(f"{t:5d} {st.engine:>8} {tr['iters']:5d} "
              f"{tr['frontier_peak']:6d} {tr['frontier_final']:6d} "
              f"{pruned:7d}  {sparkline(tr['frontier'])}")

    last = sess.history[-1].trace
    print(f"\nlast batch, iteration by iteration "
          f"(engine={last['engine']}):")
    print(f"{'it':>3} {'linf_delta':>12} {'frontier':>9} "
          f"{'delta_n':>8} {'pruned':>7}")
    for i in range(last["iters"]):
        linf = last["linf_delta"][i]
        print(f"{i:3d} {('overflow' if linf is None else f'{linf:.3e}'):>12} "
              f"{last['frontier'][i]:9d} {last['delta_n'][i]:8d} "
              f"{last['pruned'][i]:7d}")

    rep = get_registry().report()
    print("\nhost spans (where the wall-clock went):")
    for name, s in rep["spans"].items():
        print(f"  {name:28s} count={s['count']:3d} "
              f"total={s['total_s'] * 1e3:8.1f}ms "
              f"mean={s['mean_s'] * 1e3:7.2f}ms")
    print("counters (what the snapshot/session did):")
    for name, v in rep["counters"].items():
        print(f"  {name:28s} {v}")


if __name__ == "__main__":
    main()
