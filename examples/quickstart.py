"""Quickstart: Static PageRank + one DF-P dynamic update, in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (apply_batch, batch_to_device, device_graph,
                        dfp_pagerank, init_ranks, l1_error, powerlaw_graph,
                        random_batch, reference_pagerank, static_pagerank)

# 1. build a graph (self-loops added automatically — no dead ends)
g = powerlaw_graph(n=10_000, m=120_000, seed=0)

# 2. stage the hybrid ELL + tiled-CSR pull layout and run Static PageRank
dg = device_graph(g, d_p=64, tile=256)
ranks, iters = static_pagerank(dg, init_ranks(g.n))
print(f"static: converged in {int(iters)} iterations, "
      f"sum={float(ranks.sum()):.6f}")

# 3. apply a batch update (80% insertions / 20% deletions) ...
batch = random_batch(g, frac=1e-4, seed=1)
g2 = apply_batch(g, batch)
dg2 = device_graph(g2, d_p=64, tile=256)

# 4. ... and update ranks incrementally with DF-P
ranks2, iters2 = dfp_pagerank(dg2, ranks, batch_to_device(batch, g.n))
err = l1_error(np.asarray(ranks2), reference_pagerank(g2))
print(f"DF-P: converged in {int(iters2)} iterations, L1 error vs "
      f"reference = {err:.2e}")
