"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (BatchUpdate, FrontierCaps, apply_batch, batch_to_device,
                        build_graph, caps_for, device_graph, dfp_pagerank,
                        forward_device_graph, init_ranks, pull_sum,
                        random_batch, random_graph, static_pagerank)
from repro.core.pagerank import PRParams
from repro.core.partition import partition_by_degree
from repro.kernels.ref import pr_update_ref
from repro.roofline.analysis import collective_bytes

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(5, 60), m=st.integers(0, 200), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_graph_invariants(n, m, seed):
    rng = np.random.default_rng(seed)
    g = build_graph(n, rng.integers(0, n, m), rng.integers(0, n, m))
    # self-loops guarantee no dead ends
    assert np.all(g.out_degree() >= 1)
    # |in-edges| == |out-edges|
    assert g.targets.shape == g.t_sources.shape
    assert int(g.in_degree().sum()) == int(g.out_degree().sum()) == g.m


@given(n=st.integers(2, 200), d_p=st.integers(0, 50), seed=st.integers(0, 9))
@settings(**SETTINGS)
def test_partition_is_stable_permutation(n, d_p, seed):
    deg = np.random.default_rng(seed).integers(0, 64, n)
    perm, n_low = partition_by_degree(deg, d_p)
    assert sorted(perm.tolist()) == list(range(n))
    assert np.all(deg[perm[:n_low]] <= d_p)
    assert np.all(deg[perm[n_low:]] > d_p)


@given(n=st.integers(8, 80), m=st.integers(10, 300), seed=st.integers(0, 9),
       alpha=st.floats(0.5, 0.95))
@settings(max_examples=10, deadline=None)
def test_pagerank_is_probability_vector(n, m, seed, alpha):
    from repro.core.pagerank import PRParams
    g = random_graph(n, m, seed=seed)
    dg = device_graph(g, d_p=4, tile=16)
    r, _ = static_pagerank(dg, init_ranks(g.n),
                           PRParams(alpha=alpha, tau=1e-9, max_iter=200))
    r = np.asarray(r)
    assert np.all(r > 0)
    assert abs(r.sum() - 1.0) < 1e-6


@given(n=st.integers(8, 60), m=st.integers(10, 150), seed=st.integers(0, 9))
@settings(max_examples=10, deadline=None)
def test_pull_sum_equals_dense_matvec(n, m, seed):
    """pull_sum over the hybrid layout == A^T c with the dense adjacency."""
    g = random_graph(n, m, seed=seed)
    dg = device_graph(g, d_p=4, tile=8)
    rng = np.random.default_rng(seed)
    c = rng.random(n)
    dense = np.zeros((n, n))
    src, dst = g.edges()
    dense[src, dst] = 1.0
    want = dense.T @ c
    got = np.asarray(pull_sum(dg, jnp.asarray(c)))
    np.testing.assert_allclose(got, want, atol=1e-9)


@given(ins=st.integers(0, 30), dels=st.integers(0, 30),
       seed=st.integers(0, 9))
@settings(max_examples=15, deadline=None)
def test_apply_batch_monotone_edges(ins, dels, seed):
    rng = np.random.default_rng(seed)
    g = random_graph(40, 200, seed=seed)
    src, dst = g.edges()
    nl = src != dst
    k = min(dels, int(nl.sum()))
    b = BatchUpdate(del_src=src[nl][:k], del_dst=dst[nl][:k],
                    ins_src=rng.integers(0, 40, ins).astype(np.int32),
                    ins_dst=rng.integers(0, 40, ins).astype(np.int32))
    g2 = apply_batch(g, b)
    assert np.all(g2.out_degree() >= 1)
    for u, v in zip(b.ins_src, b.ins_dst):
        assert g2.has_edge(int(u), int(v))


def _dfp_oracle(g, r0, batch, params):
    """DF-P in plain numpy + the kernels/ref.py update oracle, mirroring
    `core.dynamic._df_like`: initial affected -> initial expansion -> loop
    of (expand previous frontier, pr_update_ref sweep) until L_inf <= tau."""
    n = g.n
    A = np.zeros((n, n))
    src, dst = g.edges()
    A[src, dst] = 1.0
    outdeg = g.out_degree().astype(np.float64)
    dv = np.zeros(n, bool)
    dn = np.zeros(n, bool)
    dv[np.asarray(batch.del_dst)] = True
    dn[np.asarray(batch.del_src)] = True
    dn[np.asarray(batch.ins_src)] = True
    dv |= A[dn].sum(axis=0) > 0           # initial expansion (Alg. 2 line 9)
    dn = np.zeros(n, bool)
    r = np.asarray(r0, np.float64)
    delta, i = np.inf, 0
    while delta > params.tau and i < params.max_iter:
        if i > 0:
            dv = dv | (A[dn].sum(axis=0) > 0)
        contrib = A.T @ (r / outdeg)
        r_new, aff, dn_f, dmax = pr_update_ref(
            contrib, r, outdeg, dv.astype(np.float64), alpha=params.alpha,
            inv_n=1.0 / n, tau_f=params.tau_f, tau_p=params.tau_p,
            prune=True, closed_form=True)
        r = np.asarray(r_new)
        dv = np.asarray(aff) > 0
        dn = np.asarray(dn_f) > 0
        delta = float(dmax)
        i += 1
    return r, i


@given(n=st.integers(20, 80), seed=st.integers(0, 99))
@settings(max_examples=8, deadline=None)
def test_dfp_compacted_equals_dense_equals_ref(n, seed):
    """Compacted DF-P == dense DF-P == the kernels/ref.py numpy oracle at
    1e-12 L_inf, including overflow-forcing tiny capacities (PR 8)."""
    params = PRParams(tau=1e-10, tau_f=1e-9, tau_p=1e-9, max_iter=100)
    g = random_graph(n, 4 * n, seed=seed)
    dg = device_graph(g, d_p=4, tile=16)
    r_prev, _ = static_pagerank(dg, init_ranks(n), params)
    b = random_batch(g, 0.1, seed=seed + 1)
    g2 = apply_batch(g, b)
    dg2 = device_graph(g2, d_p=4, tile=16)
    fwd2 = forward_device_graph(g2, d_p=4, tile=16)
    db = batch_to_device(b, g2.n)

    r_dense, it_dense = dfp_pagerank(dg2, r_prev, db, params)
    roomy = caps_for(dg2, n)
    tiny = FrontierCaps(bucket=(1,) * len(dg2.buckets), hi=1, tiles=1, dn=1)
    outs = {}
    for tag, caps in (("roomy", roomy), ("tiny", tiny)):
        r_c, it_c = dfp_pagerank(dg2, r_prev, db, params, fwd=fwd2,
                                 frontier_caps=caps)
        assert int(it_c) == int(it_dense), tag
        outs[tag] = np.max(np.abs(np.asarray(r_c) - np.asarray(r_dense)))
        assert outs[tag] <= 1e-12, (tag, outs[tag])
    r_ref, it_ref = _dfp_oracle(g2, r_prev, b, params)
    assert int(it_ref) == int(it_dense)
    assert np.max(np.abs(np.asarray(r_dense) - r_ref)) <= 1e-12


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), replica_groups={}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = f32[4]{0} reduce-scatter(f32[16]{0} %z), dimensions={0}
  %a2a = (f32[2,4]{1,0}, f32[2,4]{1,0}) all-to-all(f32[2,4]{1,0} %a, f32[2,4]{1,0} %b)
  %cp = u8[64]{0} collective-permute(u8[64]{0} %c)
  %notacoll = f32[9]{0} add(f32[9]{0} %p, f32[9]{0} %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 4 * 4
    assert out["all-to-all"] == 2 * 2 * 4 * 4
    assert out["collective-permute"] == 64
