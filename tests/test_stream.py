"""repro.stream: delta canonicalization, incremental snapshot equivalence,
degree crossings, capacity/rebuild fallbacks, the StreamSession engine, the
replayer, and the stream_scatter kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BatchUpdate, apply_batch, build_graph,
                        device_graph, dfp_pagerank, dfp_pagerank_compact,
                        edge_keys, init_ranks, l1_error, powerlaw_graph,
                        pull_sum, random_batch, random_graph, static_pagerank,
                        temporal_stream)
from repro.stream import (DeviceSnapshot, StreamSession, ingest, next_pow2,
                          replay, churn_workload)

CAPS = dict(d_p=8, tile=32)


def _rebuilt_pull(g):
    return device_graph(g, **CAPS)


def _rebuilt_fwd(g):
    return device_graph(g.transpose(), **CAPS)


def _assert_snapshot_matches(snap, g, rng):
    """Semantic equivalence with a from-scratch rebuild: same edge set, same
    pull semantics on both orientations (neighbor order may differ)."""
    assert snap.m == g.m
    src, dst = g.edges()
    assert np.array_equal(snap._keys, np.sort(edge_keys(g.n, src, dst)))
    c = jnp.asarray(rng.random(g.n))
    np.testing.assert_allclose(
        np.asarray(pull_sum(snap.dg, c)),
        np.asarray(pull_sum(_rebuilt_pull(g), c)), atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(pull_sum(snap.fwd_dg, c)),
        np.asarray(pull_sum(_rebuilt_fwd(g), c)), atol=1e-12)
    np.testing.assert_array_equal(np.asarray(snap.dg.out_deg),
                                  g.out_degree())
    np.testing.assert_array_equal(np.asarray(snap.fwd_dg.out_deg),
                                  g.in_degree())


# ---------------------------------------------------------------------------
# delta
# ---------------------------------------------------------------------------

def test_ingest_dedups_and_filters_self_loop_deletions():
    b = BatchUpdate(del_src=np.array([1, 1, 3], np.int32),
                    del_dst=np.array([2, 2, 3], np.int32),
                    ins_src=np.array([4, 4], np.int32),
                    ins_dst=np.array([5, 5], np.int32))
    d = ingest(b, 10)
    assert d.nd == 1 and d.ni == 1          # dup pairs collapsed
    assert (d.del_src[0], d.del_dst[0]) == (1, 2)   # (3,3) self-loop dropped
    assert (d.ins_src[0], d.ins_dst[0]) == (4, 5)


def test_ingest_coalesce_modes():
    b = BatchUpdate(del_src=np.array([1], np.int32),
                    del_dst=np.array([2], np.int32),
                    ins_src=np.array([1], np.int32),
                    ins_dst=np.array([2], np.int32))
    d = ingest(b, 10)                        # del_first == apply_batch
    assert d.nd == 0 and d.ni == 1
    d = ingest(b, 10, coalesce="cancel")     # insert-then-delete cancels
    assert d.nd == 0 and d.ni == 0
    with pytest.raises(ValueError):
        ingest(b, 10, coalesce="bogus")


def test_delta_to_device_pads_pow2_with_sentinel():
    b = random_batch(random_graph(50, 400, seed=0), 0.05, seed=1)
    d = ingest(b, 50)
    db = d.to_device()
    cap = next_pow2(max(d.nd, d.ni))
    assert db.ins_src.shape == (cap,) == db.del_src.shape
    assert np.all(np.asarray(db.ins_src)[d.ni:] == 50)   # sentinel = n


def test_ingest_matches_apply_batch_semantics():
    g = random_graph(60, 500, seed=2)
    b = random_batch(g, 0.1, seed=3)
    g_ref = apply_batch(g, b)
    d = ingest(b, g.n)
    snap = DeviceSnapshot(g, **CAPS)
    snap.apply(d)
    got = snap.graph()
    src, dst = g_ref.edges()
    assert np.array_equal(snap._keys, np.sort(edge_keys(g.n, src, dst)))
    assert got.m == g_ref.m


# ---------------------------------------------------------------------------
# snapshot: incremental equivalence
# ---------------------------------------------------------------------------

def test_snapshot_tracks_rebuild_across_churn_batches():
    g = powerlaw_graph(800, 8000, seed=1)
    snap = DeviceSnapshot(g, **CAPS)
    rng = np.random.default_rng(0)
    gg = g
    rebuilds = 0
    for t in range(6):
        b = random_batch(gg, 0.01, seed=100 + t)
        st = snap.apply(ingest(b, g.n))
        rebuilds += st.rebuilt
        gg = apply_batch(gg, b)
        _assert_snapshot_matches(snap, gg, rng)
    assert rebuilds == 0                     # stayed incremental throughout
    assert snap.fragmentation() <= snap.frag_budget


def test_snapshot_degree_crossing_round_trip():
    """Push one vertex across d_p (ELL -> tiles), then back below low_water
    (tiles -> ELL); the layout must match a rebuild at every step."""
    n, hub = 64, 7
    rng = np.random.default_rng(4)
    g = build_graph(n, np.array([0, 1], np.int32), np.array([2, 3], np.int32))
    # a tiny graph would trip the batch-size/fragmentation rebuild triggers;
    # disable them so the *incremental* migration path is what's tested
    snap = DeviceSnapshot(g, d_p=4, tile=8, low_water=2,
                          rebuild_threshold=2.0, frag_budget=2.0)
    gg = g
    srcs = np.arange(8, 28, dtype=np.int32)   # 20 in-edges onto the hub
    for k in range(0, 20, 5):
        b = BatchUpdate(del_src=np.zeros(0, np.int32),
                        del_dst=np.zeros(0, np.int32),
                        ins_src=srcs[k:k + 5],
                        ins_dst=np.full(5, hub, np.int32))
        st = snap.apply(ingest(b, n))
        assert not st.rebuilt
        gg = apply_batch(gg, b)
    assert not bool(snap._pull.is_low[hub])   # crossed to the tile side
    c = jnp.asarray(rng.random(n))
    np.testing.assert_allclose(
        np.asarray(pull_sum(snap.dg, c)),
        np.asarray(pull_sum(device_graph(gg, d_p=4, tile=8), c)), atol=1e-12)
    # now delete back down below low_water = 2 (keep 1 in-edge + self-loop)
    b = BatchUpdate(del_src=srcs[:19], del_dst=np.full(19, hub, np.int32),
                    ins_src=np.zeros(0, np.int32),
                    ins_dst=np.zeros(0, np.int32))
    st = snap.apply(ingest(b, n))
    assert not st.rebuilt
    gg = apply_batch(gg, b)
    assert bool(snap._pull.is_low[hub])       # demoted back into the ELL
    np.testing.assert_allclose(
        np.asarray(pull_sum(snap.dg, c)),
        np.asarray(pull_sum(device_graph(gg, d_p=4, tile=8), c)), atol=1e-12)


def test_snapshot_hysteresis_parks_subdp_vertices():
    """With low_water < d_p, a vertex dropping just below d_p stays on the
    tile side (counted as fragmentation) instead of thrashing."""
    n, hub = 32, 3
    g = build_graph(n, np.zeros(0, np.int32), np.zeros(0, np.int32))
    snap = DeviceSnapshot(g, d_p=4, tile=8, low_water=1,
                          rebuild_threshold=2.0, frag_budget=2.0)
    srcs = np.arange(8, 14, dtype=np.int32)
    ins = BatchUpdate(del_src=np.zeros(0, np.int32),
                      del_dst=np.zeros(0, np.int32),
                      ins_src=srcs, ins_dst=np.full(6, hub, np.int32))
    snap.apply(ingest(ins, n))
    assert not bool(snap._pull.is_low[hub])
    dele = BatchUpdate(del_src=srcs[:3], del_dst=np.full(3, hub, np.int32),
                       ins_src=np.zeros(0, np.int32),
                       ins_dst=np.zeros(0, np.int32))
    snap.apply(ingest(dele, n))
    assert not bool(snap._pull.is_low[hub])   # parked: deg 4 > low_water 1
    assert snap.fragmentation() > 0.0


def test_snapshot_capacity_overflow_rebuilds_with_growth():
    n = 128
    g = build_graph(n, np.zeros(0, np.int32), np.zeros(0, np.int32))
    snap = DeviceSnapshot(g, d_p=4, tile=8,
                          hi_headroom=1.0, tile_headroom=1.0)
    t_cap0 = snap._caps["t_cap"]
    # flood one vertex with more in-edges than the whole tile pool can hold
    srcs = np.arange(1, 1 + t_cap0 * 8 + 8, dtype=np.int32) % n
    srcs = np.unique(srcs[srcs != 5])
    b = BatchUpdate(del_src=np.zeros(0, np.int32),
                    del_dst=np.zeros(0, np.int32),
                    ins_src=srcs, ins_dst=np.full(srcs.size, 5, np.int32))
    snap.rebuild_threshold = 1.1              # don't shortcut via batch size
    st = snap.apply(ingest(b, n))
    assert st.rebuilt and st.rebuild_reason.startswith("capacity")
    assert snap._caps["t_cap"] > t_cap0       # pool grew (pow2)
    gg = apply_batch(g, b)
    _assert_snapshot_matches(snap, gg, np.random.default_rng(5))


def test_snapshot_large_batch_takes_rebuild_path():
    g = powerlaw_graph(500, 4000, seed=6)
    snap = DeviceSnapshot(g, **CAPS, rebuild_threshold=0.01)
    b = random_batch(g, 0.2, seed=7)          # far above the threshold
    st = snap.apply(ingest(b, g.n))
    assert st.rebuilt and st.rebuild_reason == "batch_too_large"
    _assert_snapshot_matches(snap, apply_batch(g, b),
                             np.random.default_rng(8))


def test_snapshot_pallas_scatter_matches_jnp():
    g = powerlaw_graph(300, 2500, seed=9)
    sp = DeviceSnapshot(g, **CAPS, scatter_impl="pallas")
    sj = DeviceSnapshot(g, **CAPS)
    gg = g
    rng = np.random.default_rng(10)
    for t in range(3):
        b = random_batch(gg, 0.01, seed=20 + t)
        d = ingest(b, g.n)
        sp.apply(d)
        sj.apply(d)
        gg = apply_batch(gg, b)
        c = jnp.asarray(rng.random(g.n))
        np.testing.assert_array_equal(np.asarray(pull_sum(sp.dg, c)),
                                      np.asarray(pull_sum(sj.dg, c)))


# ---------------------------------------------------------------------------
# stream_scatter kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_scatter_rows_kernel_matches_at_set(dtype):
    from repro.kernels import scatter_rows
    rng = np.random.default_rng(11)
    dst = jnp.asarray(rng.integers(0, 100, (40, 8)).astype(dtype))
    rows = np.array([3, 17, 3, 3], np.int32)   # pad convention: repeat row 0
    new = rng.integers(0, 100, (4, 8)).astype(dtype)
    new[2] = new[0]
    new[3] = new[0]
    got = scatter_rows(dst, jnp.asarray(rows), jnp.asarray(new),
                       interpret=True)
    want = np.asarray(dst).copy()
    want[3], want[17] = new[0], new[1]
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# session + replay
# ---------------------------------------------------------------------------

def test_session_tracks_static_recompute_on_temporal_stream():
    base, batches = temporal_stream(2000, 30000, n_batches=60, seed=12)
    sess = StreamSession(base, **CAPS)
    gg = base
    for b in batches[:5]:
        r = sess.apply(b)
        gg = apply_batch(gg, b)
        ref, _ = static_pagerank(device_graph(gg, **CAPS),
                                 init_ranks(gg.n), sess.params)
        assert l1_error(np.asarray(r), np.asarray(ref)) < 1e-8
    assert not any(h.snapshot.rebuilt for h in sess.history)


def test_session_handles_deletion_churn():
    g = powerlaw_graph(1000, 10000, seed=13)
    sess = StreamSession(g, **CAPS)
    gg = g
    for b in churn_workload(g, 2e-3, 4, seed=14):
        r = sess.apply(b)
        gg = apply_batch(gg, b)
        ref, _ = static_pagerank(device_graph(gg, **CAPS),
                                 init_ranks(gg.n), sess.params)
        assert l1_error(np.asarray(r), np.asarray(ref)) < 1e-8


def test_session_engine_selection_and_override():
    g = powerlaw_graph(600, 6000, seed=15)
    # threshold is on estimated-initial-frontier / |V|: generous -> compact
    sess = StreamSession(g, **CAPS, engine="auto", compact_threshold=0.5)
    sess.apply(random_batch(g, 1e-3, seed=16))
    assert sess.history[-1].engine == "compact"
    sess.apply(random_batch(g, 0.2, seed=17))
    assert sess.history[-1].engine == "dense"
    forced = StreamSession(g, **CAPS, engine="dense")
    forced.apply(random_batch(g, 1e-3, seed=18))
    assert forced.history[-1].engine == "dense"
    with pytest.raises(ValueError):
        StreamSession(g, **CAPS, engine="warp")


def test_session_topk_matches_argsort():
    g = powerlaw_graph(500, 4000, seed=19)
    sess = StreamSession(g, **CAPS)
    sess.apply(random_batch(g, 1e-3, seed=20))
    ids, vals = sess.topk(10)
    r = np.asarray(sess.ranks)
    want = np.argsort(-r)[:10]
    np.testing.assert_array_equal(np.sort(ids), np.sort(want))
    np.testing.assert_allclose(vals, r[ids])


def test_replay_records_latency_and_error():
    base, batches = temporal_stream(800, 10000, n_batches=20, seed=21)
    sess = StreamSession(base, **CAPS)
    recs = replay(sess, batches[:4], verify_every=2)
    assert len(recs) == 4
    assert all(r.total_s > 0 for r in recs)
    assert recs[0].l1_vs_static is None and recs[1].l1_vs_static is not None
    assert all(r.l1_vs_static < 1e-8 for r in recs if r.l1_vs_static
               is not None)


# ---------------------------------------------------------------------------
# pre-staged snapshots through the core drivers
# ---------------------------------------------------------------------------

def test_drivers_accept_snapshot_directly():
    g = powerlaw_graph(400, 3000, seed=22)
    snap = DeviceSnapshot(g, **CAPS)
    r0 = init_ranks(g.n)
    r_snap, _ = static_pagerank(snap, r0)
    r_dg, _ = static_pagerank(device_graph(g, **CAPS), r0)
    np.testing.assert_array_equal(np.asarray(r_snap), np.asarray(r_dg))
    b = random_batch(g, 1e-3, seed=23)
    d = ingest(b, g.n)
    snap.apply(d)
    db = d.to_device()
    r1, _ = dfp_pagerank(snap, r_dg, db)
    r2, _ = dfp_pagerank_compact(snap, None, r_dg, db)
    assert l1_error(np.asarray(r1), np.asarray(r2)) < 1e-12


# ---------------------------------------------------------------------------
# acceptance scale (paper protocol)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_acceptance_20k_temporal_stream():
    """ISSUE acceptance: 20k-vertex / 300k-edge temporal stream; every batch's
    session ranks within L1 1e-8 of static PageRank recomputed from scratch."""
    base, batches = temporal_stream(20_000, 300_000, n_batches=1000, seed=7)
    sess = StreamSession(base, d_p=64, tile=256)
    gg = base
    for b in batches[:3]:
        r = sess.apply(b)
        gg = apply_batch(gg, b)
        ref, _ = static_pagerank(device_graph(gg, d_p=64, tile=256),
                                 init_ranks(gg.n), sess.params)
        assert l1_error(np.asarray(r), np.asarray(ref)) < 1e-8
    assert not any(h.snapshot.rebuilt for h in sess.history)
