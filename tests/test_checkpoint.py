"""Checkpoint/restart + fault tolerance + elasticity tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.train import (elastic_pagerank_resume, latest_step,
                         list_checkpoints, restore_checkpoint,
                         run_with_restarts, save_checkpoint, train)
from repro.train.elastic import RunState


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "d": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 5, tree, extra={"note": "x"})
    out, extra, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 5 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_atomic_commit_survives_partial_write(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    save_checkpoint(str(tmp_path), 1, tree)
    # fake a crashed save: stale tmp dir must be ignored
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_checksum_verification(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    fn = os.path.join(path, "leaf_00000.npy")
    arr = np.load(fn)
    arr[0] = 999
    np.save(fn, arr)
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), tree)


def test_run_with_restarts_recovers(tmp_path):
    calls = {"fails": 0}

    def init_fn():
        return RunState(step=0, tree={"x": jnp.zeros(())}, extra={})

    def step_fn(st):
        return RunState(step=st.step + 1,
                        tree={"x": st.tree["x"] + 1.0}, extra={})

    def fail_injector(step):
        if step == 7 and calls["fails"] == 0:
            calls["fails"] += 1
            raise RuntimeError("simulated node failure")

    out = run_with_restarts(step_fn, init_fn, str(tmp_path), total_steps=10,
                            ckpt_every=2, fail_injector=fail_injector)
    assert out.step == 10
    assert float(out.tree["x"]) == 10.0      # no lost or repeated updates
    assert calls["fails"] == 1


def test_train_restart_continues(tmp_path):
    cfg = smoke_config(get_config("smollm-360m"))
    with pytest.raises(RuntimeError):
        train(cfg, steps=6, batch=2, seq=32, ckpt_dir=str(tmp_path),
              ckpt_every=2, log_every=1, fail_at=4)
    assert latest_step(str(tmp_path)) == 4
    params, hist = train(cfg, steps=6, batch=2, seq=32,
                         ckpt_dir=str(tmp_path), ckpt_every=2, log_every=1)
    assert hist[-1]["step"] == 6
    assert np.isfinite(hist[-1]["loss"])


def test_elastic_pagerank_resume(tmp_path):
    from repro.core import powerlaw_graph
    g = powerlaw_graph(128, 900, seed=0)
    r = np.random.default_rng(0).random(g.n)
    dv = np.zeros(g.n, bool)
    dv[:5] = True
    save_checkpoint(str(tmp_path), 3, {"r": jnp.asarray(r),
                                       "dv": jnp.asarray(dv)})
    sg, r2, dv2 = elastic_pagerank_resume(g, str(tmp_path), new_nd=4,
                                          d_p=8, tile=32)
    assert sg.nd == 4
    np.testing.assert_allclose(r2.reshape(-1)[:g.n], r)
    assert dv2.reshape(-1)[:g.n].sum() == 5
    # different device count, same data
    sg8, r8, _ = elastic_pagerank_resume(g, str(tmp_path), new_nd=8,
                                         d_p=8, tile=32)
    assert sg8.nd == 8
    np.testing.assert_allclose(r8.reshape(-1)[:g.n], r)
