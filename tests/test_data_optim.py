"""Data pipeline, optimizers, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLM, batch_for
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, compress_grads, decompress_grads,
                         ef_apply, ef_init)


def test_synthetic_pipeline_seekable():
    src = SyntheticLM(vocab=100, batch=4, seq=32, seed=1)
    a = src.batch_at(7)
    b = src.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    c = src.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 32)
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 100


def test_batch_for_matches_arch_inputs():
    for name in ("qwen2-1.5b", "musicgen-large", "qwen2-vl-2b"):
        cfg = smoke_config(get_config(name))
        b = batch_for(cfg, 2, 16, 0)
        if cfg.embed_inputs:
            assert b["embeddings"].shape == (2, 16, cfg.d_model)
            assert b["labels"].shape == (2, 16)
        else:
            assert b["tokens"].shape == (2, 16)
        if cfg.rope == "mrope":
            assert b["positions"].shape == (2, 3, 16)


def _quad_setup():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    return params, grads


def test_adamw_descends():
    params, grads = _quad_setup()
    st = adamw_init(params)
    p2, st2, gn = adamw_update(grads, st, params, lr=0.1, wd=0.0)
    assert float(gn) > 0
    # moves against the gradient
    assert float(p2["w"][0]) < 1.0
    assert float(p2["w"][1]) > -2.0
    assert int(st2.step) == 1


def test_adafactor_descends_and_is_factored():
    params = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    grads = {"w": jnp.full((8, 4), 0.5), "b": jnp.full((4,), 0.5)}
    st = adafactor_init(params)
    assert st.vr["w"].shape == (8,)
    assert st.vc["w"].shape == (4,)
    p2, st2, _ = adafactor_update(grads, st, params, lr=0.1)
    assert float(p2["w"][0, 0]) < 1.0


def test_compression_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    q, s = compress_grads(g)
    assert q["a"].dtype == jnp.int8
    rec = decompress_grads(q, s)
    rel = float(jnp.max(jnp.abs(rec["a"] - g["a"]))) / float(
        jnp.max(jnp.abs(g["a"])))
    assert rel < 0.01   # 1/127 per-tensor quantization


def test_error_feedback_is_unbiased_over_steps():
    rng = np.random.default_rng(1)
    g = {"a": jnp.asarray(rng.standard_normal(256), jnp.float32)}
    res = ef_init(g)
    total_sent = jnp.zeros(256)
    for _ in range(50):
        q, s, res = ef_apply(g, res)
        total_sent = total_sent + decompress_grads(q, s)["a"]
    avg = total_sent / 50
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g["a"]),
                               atol=0.02)
