"""Degree-bucketed ELL parity suite (layout acceptance gate).

Three representations of the same graph must agree to 1e-12 L_inf on every
engine: the degree-bucketed default layout, the paper's single-width hybrid
forced via widths=(d_p,), and the pure-numpy / kernels.ref oracles. Covers
static PageRank, dense DF-P, compact DF-P, a streamed batch sequence that
forces bucket-crossing migrations, and the d_p=0 all-CSR degenerate case.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BatchUpdate, PRParams, apply_batch, batch_to_device,
                        build_hybrid, dfp_pagerank, dfp_pagerank_compact,
                        init_ranks, l1_error, powerlaw_graph, pull_max,
                        pull_sum, random_batch, reference_pagerank,
                        static_pagerank, to_device)
from repro.core.pagerank import update_ranks
from repro.kernels import pull_sum_kernels, update_ranks_kernel
from repro.kernels.ref import pr_update_ref
from repro.stream import DeviceSnapshot, ingest

D_P, TILE = 8, 32
TOL = 1e-12
STEP = dict(alpha=0.85, tau_f=1e-6, tau_p=1e-6, prune=True,
            closed_form=True, track_frontier=True)


def _linf(a, b):
    return float(np.max(np.abs(np.asarray(a, np.float64)
                               - np.asarray(b, np.float64))))


def _layout_pair(g):
    """(bucketed default, forced single-width) device graphs of g."""
    dg_b = to_device(build_hybrid(g, d_p=D_P, tile=TILE))
    dg_s = to_device(build_hybrid(g, d_p=D_P, tile=TILE, widths=(D_P,)))
    assert len(dg_b.buckets) > 1      # the graph actually exercises buckets
    assert len(dg_s.buckets) == 1
    return dg_b, dg_s


def _pull_oracle(g, c):
    seg = np.repeat(np.arange(g.n), np.diff(g.t_offsets))
    return np.bincount(seg, weights=np.asarray(c, np.float64)[g.t_sources],
                       minlength=g.n)


# ---------------------------------------------------------------------------
# primitive parity: pull kernels
# ---------------------------------------------------------------------------

def test_pull_sum_parity_across_layouts_and_kernels():
    g = powerlaw_graph(300, 2500, seed=0)
    dg_b, dg_s = _layout_pair(g)
    c = jnp.asarray(np.random.default_rng(1).random(g.n))
    want = _pull_oracle(g, c)
    for dg in (dg_b, dg_s):
        assert _linf(pull_sum(dg, c), want) <= TOL
        assert _linf(pull_sum_kernels(dg, c), want) <= TOL


def test_pull_max_parity_across_layouts():
    g = powerlaw_graph(300, 2500, seed=2)
    dg_b, dg_s = _layout_pair(g)
    x = jnp.asarray(np.random.default_rng(3).random(g.n))
    assert _linf(pull_max(dg_b, x), pull_max(dg_s, x)) == 0.0


# ---------------------------------------------------------------------------
# one-step parity against kernels/ref.py
# ---------------------------------------------------------------------------

def test_update_ranks_step_matches_pr_update_ref():
    g = powerlaw_graph(250, 2000, seed=4)
    dg_b, dg_s = _layout_pair(g)
    rng = np.random.default_rng(5)
    r = jnp.asarray(rng.random(g.n) / g.n + 1.0 / g.n)
    aff = jnp.asarray(rng.random(g.n) < 0.7)
    contrib = _pull_oracle(g, np.asarray(r) / g.out_degree())
    want_r, want_aff, _, want_d = pr_update_ref(
        contrib, np.asarray(r), g.out_degree().astype(np.float64),
        np.asarray(aff), alpha=STEP["alpha"], inv_n=1.0 / g.n,
        tau_f=STEP["tau_f"], tau_p=STEP["tau_p"], prune=True,
        closed_form=True)
    for fn in (update_ranks, update_ranks_kernel):
        for dg in (dg_b, dg_s):
            r_new, aff_new, _, delta = fn(dg, r, aff, **STEP)
            assert _linf(r_new, want_r) <= TOL
            assert np.array_equal(np.asarray(aff_new), want_aff)
            assert abs(float(delta) - float(want_d)) <= TOL


# ---------------------------------------------------------------------------
# engine parity: static, dense DF-P, compact DF-P
# ---------------------------------------------------------------------------

def test_static_pagerank_parity():
    g = powerlaw_graph(300, 2500, seed=6)
    dg_b, dg_s = _layout_pair(g)
    r_b, _ = static_pagerank(dg_b, init_ranks(g.n))
    r_s, _ = static_pagerank(dg_s, init_ranks(g.n))
    r_k, _ = static_pagerank(dg_b, init_ranks(g.n),
                             pull_sum_fn=pull_sum_kernels)
    assert _linf(r_b, r_s) <= TOL
    assert _linf(r_b, r_k) <= TOL
    assert l1_error(np.asarray(r_b), reference_pagerank(g)) < 1e-5


def _dfp_setup(seed):
    g = powerlaw_graph(300, 2500, seed=seed)
    dg_b, _ = _layout_pair(g)
    r_prev, _ = static_pagerank(dg_b, init_ranks(g.n))
    b = random_batch(g, 0.02, seed=seed + 1)
    g2 = apply_batch(g, b)
    db = batch_to_device(b, g2.n)
    return g2, r_prev, db


def test_dfp_dense_parity():
    g2, r_prev, db = _dfp_setup(7)
    dg_b, dg_s = _layout_pair(g2)
    r_b, _ = dfp_pagerank(dg_b, r_prev, db)
    r_s, _ = dfp_pagerank(dg_s, r_prev, db)
    assert _linf(r_b, r_s) <= TOL
    assert l1_error(np.asarray(r_b), reference_pagerank(g2)) < 1e-3


def test_dfp_compact_parity():
    g2, r_prev, db = _dfp_setup(9)
    dg_b, dg_s = _layout_pair(g2)
    gt = g2.transpose()
    fwd_b = to_device(build_hybrid(gt, d_p=D_P, tile=TILE))
    fwd_s = to_device(build_hybrid(gt, d_p=D_P, tile=TILE, widths=(D_P,)))
    r_b, _ = dfp_pagerank_compact(dg_b, fwd_b, r_prev, db)
    r_s, _ = dfp_pagerank_compact(dg_s, fwd_s, r_prev, db)
    assert _linf(r_b, r_s) <= TOL
    assert l1_error(np.asarray(r_b), reference_pagerank(g2)) < 1e-3


# ---------------------------------------------------------------------------
# streamed batches forcing bucket-crossing migrations
# ---------------------------------------------------------------------------

def _fan_batch(g, v, k, sign):
    """Insert (sign=+1) or delete (sign=-1) k in-edges of v, choosing fresh
    (resp. existing) sources deterministically."""
    srcs = []
    for u in range(g.n):
        if u == v or len(srcs) == k:
            continue
        if (sign > 0) != g.has_edge(u, v):
            srcs.append(u)
    srcs = np.asarray(srcs[:k], np.int32)
    dsts = np.full(srcs.shape, v, np.int32)
    e = np.zeros(0, np.int32)
    if sign > 0:
        return BatchUpdate(del_src=e, del_dst=e, ins_src=srcs, ins_dst=dsts)
    return BatchUpdate(del_src=srcs, del_dst=dsts, ins_src=e, ins_dst=e)


def test_streamed_batches_cross_buckets_and_stay_exact():
    g = powerlaw_graph(200, 1200, seed=11)
    snap = DeviceSnapshot(g, d_p=D_P, tile=TILE)
    widths = snap._pull.widths
    assert len(widths) > 1
    # a vertex sitting in the narrowest bucket of the pull (in-degree) side
    indeg = g.in_degree()
    v = int(np.nonzero(indeg == 1)[0][0])
    assert snap._pull.bucket_of[v] == 0
    r_prev, _ = static_pagerank(snap.dg, init_ranks(g.n))
    # grow v's in-degree past every bucket width and into the CSR side,
    # then shrink it back below low_water: promotion + demotion crossings
    for k, sign in ((D_P - 1, +1), (3 * D_P, +1), (4 * D_P - 2, -1)):
        b = _fan_batch(g, v, k, sign)
        g = apply_batch(g, b)
        snap.apply(ingest(b, g.n))
        db = batch_to_device(b, g.n)
        dg_s = to_device(build_hybrid(g, d_p=D_P, tile=TILE, widths=(D_P,)))
        r_snap, _ = dfp_pagerank(snap, r_prev, db)
        r_single, _ = dfp_pagerank(dg_s, r_prev, db)
        assert _linf(r_snap, r_single) <= TOL
        r_prev = r_snap
    assert snap._pull.migrations > 0
    assert l1_error(np.asarray(r_prev), reference_pagerank(g)) < 1e-3


# ---------------------------------------------------------------------------
# d_p = 0: widths=() puts every vertex on the CSR side (single format)
# ---------------------------------------------------------------------------

def test_d_p_zero_all_csr_parity():
    g = powerlaw_graph(200, 1500, seed=13)
    lay = build_hybrid(g, d_p=0, tile=TILE)
    assert lay.widths == () and not lay.is_low.any()
    dg = to_device(lay)
    assert dg.buckets == ()
    c = jnp.asarray(np.random.default_rng(14).random(g.n))
    assert _linf(pull_sum(dg, c), _pull_oracle(g, c)) <= TOL
    assert _linf(pull_sum_kernels(dg, c), _pull_oracle(g, c)) <= TOL
    r0 = init_ranks(g.n)
    r, _ = static_pagerank(dg, r0)
    assert l1_error(np.asarray(r), reference_pagerank(g)) < 1e-5
    # self-loops guarantee indeg >= 1, so d_p=0 puts every vertex high-side
    # and the kernel runs the SAME hi-slot epilogue as every other layout
    # (the bespoke staged fallback is gone)
    aff = jnp.ones(g.n, jnp.bool_)
    ra, _, _, da = update_ranks(dg, r0, aff, **STEP)
    rb, _, _, db_ = update_ranks_kernel(dg, r0, aff, **STEP)
    assert _linf(ra, rb) <= TOL
    assert abs(float(da) - float(db_)) <= TOL


# ---------------------------------------------------------------------------
# frontier-compacted kernel sweeps (PR 8): active lists == full sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d_p", [D_P, 0])
def test_update_ranks_kernel_active_parity(d_p):
    """`update_ranks_kernel(active=...)` must be bit-identical to its own
    full sweep (and the non-kernel engine path) on both the bucketed and
    the d_p=0 all-CSR layouts — the same epilogue runs over compacted
    active-slot lists instead of every slot."""
    from repro.core import active_frontier, caps_for
    g = powerlaw_graph(250, 2000, seed=17)
    dg = to_device(build_hybrid(g, d_p=d_p, tile=TILE))
    rng = np.random.default_rng(18)
    r = jnp.asarray(rng.random(g.n) / g.n + 1.0 / g.n)
    dv = jnp.asarray(rng.random(g.n) < 0.08)
    caps = caps_for(dg, int(jnp.sum(dv)))
    af = active_frontier(dg.buckets, dg.hi_ids, dg.hi_rowmap, dv, caps)
    assert not bool(af.overflow)
    full = update_ranks_kernel(dg, r, dv, **STEP)
    act = update_ranks_kernel(dg, r, dv, active=af, **STEP)
    ref = update_ranks(dg, r, dv, **STEP)
    for a, b, c in zip(full, act, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert _linf(b, c) <= TOL
