"""repro.obs: trace parity, spans/counters, structured sinks, the gate.

The load-bearing invariant is *telemetry neutrality*: `trace=True` threads a
TraceBuffer through every engine's while_loop but must not change a single
bit of the rank output or the iteration count. Host spans/counters live
entirely outside jit, so only their bookkeeping needs testing. The sharded
engines get the same parity check under a forced 4-device host mesh in a
subprocess (XLA fixes the device count at first init).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (apply_batch, batch_to_device, device_graph,
                        df_pagerank, df_pagerank_compact, dfp_pagerank,
                        dfp_pagerank_compact, dt_pagerank,
                        forward_device_graph, init_ranks, nd_pagerank,
                        powerlaw_graph, random_batch, static_pagerank)
from repro.obs.report import (RunReport, load_report, parse_derived,
                              validate_report)
from repro.obs.spans import Registry, get_registry, reset_registry
from repro.obs.trace import (ENGINE_IDS, maybe_summary, trace_init,
                             trace_record, trace_summary)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- TraceBuffer primitives ---------------------------------------------------

def test_trace_init_sentinels_and_record():
    tb = trace_init(8, jnp.float64, "dfp")
    assert int(tb.engine) == ENGINE_IDS["dfp"]
    assert np.all(np.isnan(np.asarray(tb.linf)))
    assert np.all(np.asarray(tb.frontier) == -1)
    tb = trace_record(tb, jnp.asarray(3), linf=0.5, frontier=7,
                      delta_n=2, pruned=1)
    assert np.asarray(tb.linf)[3] == 0.5
    assert np.asarray(tb.frontier)[3] == 7
    # untouched lanes keep their sentinels
    assert np.isnan(np.asarray(tb.linf)[0])
    assert np.asarray(tb.pruned)[0] == -1


def test_trace_record_out_of_cap_drops():
    tb = trace_init(4, jnp.float64, "static")
    tb2 = trace_record(tb, jnp.asarray(9), linf=1.0, frontier=1,
                       delta_n=0, pruned=0)
    np.testing.assert_array_equal(np.asarray(tb2.frontier),
                                  np.asarray(tb.frontier))


def test_trace_summary_trims_and_sanitizes():
    tb = trace_init(6, jnp.float64, "dfp_compact")
    tb = trace_record(tb, jnp.asarray(0), linf=jnp.inf, frontier=5,
                      delta_n=1, pruned=0)
    tb = trace_record(tb, jnp.asarray(1), linf=0.25, frontier=3,
                      delta_n=0, pruned=2)
    s = trace_summary(tb, 2)
    assert s["engine"] == "dfp_compact"
    assert s["iters"] == 2
    assert s["linf_delta"] == [None, 0.25]      # inf -> None (strict JSON)
    assert s["frontier"] == [5, 3]
    assert s["frontier_peak"] == 5 and s["frontier_final"] == 3
    assert s["linf_final"] == 0.25
    json.dumps(s, allow_nan=False)              # must be strict-JSON safe


def test_maybe_summary_passthrough():
    out, s = maybe_summary(("r", 3), False)
    assert out == ("r", 3) and s is None
    tb = trace_record(trace_init(4, jnp.float64, "nd"), jnp.asarray(0),
                      linf=0.1, frontier=2, delta_n=0, pruned=0)
    (r, it), s = maybe_summary(("r", 1, tb), True)
    assert r == "r" and it == 1 and s["engine"] == "nd"


# -- spans / counters ---------------------------------------------------------

def test_registry_spans_and_counters():
    reg = Registry()
    reg.inc("a")
    reg.inc("a", 4)
    assert reg.counter("a") == 5
    with reg.span("phase"):
        pass
    with reg.span("phase", annotate=True):
        pass
    st = reg.span_stats("phase")
    assert st.count == 2 and st.total_s >= 0.0
    rep = reg.report()
    assert rep["counters"]["a"] == 5
    assert rep["spans"]["phase"]["count"] == 2
    reg.reset()
    assert reg.report() == {"spans": {}, "counters": {}}


def test_default_registry_reset():
    reset_registry()
    get_registry().inc("x")
    assert get_registry().counter("x") == 1
    reset_registry()
    assert get_registry().counter("x") == 0


def test_span_timer_exceptions_still_recorded():
    reg = Registry()
    with pytest.raises(ValueError):
        with reg.span("boom"):
            raise ValueError()
    assert reg.span_stats("boom").count == 1


# -- engine parity: trace on == trace off (bit-identical) ---------------------

@pytest.fixture(scope="module")
def small_case():
    g0 = powerlaw_graph(800, 8000, seed=2)
    b = random_batch(g0, 0.003, seed=5)
    g = apply_batch(g0, b)
    caps = dict(d_p=16, tile=64)
    dg0 = device_graph(g0, **caps)
    dg = device_graph(g, **caps)
    fwd = forward_device_graph(g, **caps)
    db = batch_to_device(b, g.n)
    r_prev, _ = static_pagerank(dg0, init_ranks(g0.n))
    return dict(dg0=dg0, dg=dg, fwd=fwd, db=db, r_prev=r_prev, n=g.n)


def _assert_parity(run, engine, min_iters=1):
    r0, it0 = run(trace=False)
    r1, it1, tb = run(trace=True)
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
    assert int(it0) == int(it1)
    s = trace_summary(tb, it1)
    assert s["engine"] == engine
    assert s["iters"] == int(it1) >= min_iters
    front = np.asarray(tb.frontier)
    assert np.all(front[:int(it1)] >= 0)        # every lane written
    if int(it1) < tb.cap:
        assert front[int(it1)] == -1            # and nothing beyond
    return s


def test_static_trace_parity(small_case):
    c = small_case
    s = _assert_parity(
        lambda trace: static_pagerank(c["dg"], init_ranks(c["n"]),
                                      trace=trace), "static", min_iters=2)
    assert s["frontier"] == [c["n"]] * s["iters"]


def test_nd_trace_parity(small_case):
    c = small_case
    _assert_parity(lambda trace: nd_pagerank(c["dg"], c["r_prev"],
                                             trace=trace), "nd")


def test_dt_trace_parity(small_case):
    c = small_case
    _assert_parity(
        lambda trace: dt_pagerank(c["dg"], c["dg0"], c["r_prev"], c["db"],
                                  trace=trace), "dt")


def test_df_dfp_dense_trace_parity(small_case):
    c = small_case
    _assert_parity(lambda trace: df_pagerank(c["dg"], c["r_prev"], c["db"],
                                             trace=trace), "df")
    s = _assert_parity(
        lambda trace: dfp_pagerank(c["dg"], c["r_prev"], c["db"],
                                   trace=trace), "dfp")
    assert all(p >= 0 for p in s["pruned"])


def test_compact_trace_parity(small_case):
    c = small_case
    _assert_parity(
        lambda trace: df_pagerank_compact(c["dg"], c["fwd"], c["r_prev"],
                                          c["db"], trace=trace), "df_compact")
    s = _assert_parity(
        lambda trace: dfp_pagerank_compact(c["dg"], c["fwd"], c["r_prev"],
                                           c["db"], trace=trace),
        "dfp_compact")
    # the frontier series must decay to a small tail (paper Fig. 3 shape)
    assert s["frontier"][-1] <= s["frontier_peak"]


_SHARDED_SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import powerlaw_graph, random_batch, apply_batch
    from repro.core.distributed import (build_sharded,
                                        distributed_static_pagerank,
                                        distributed_dfp_pagerank,
                                        initial_affected_sharded)
    from repro.core.distributed2d import build_sharded_2d, pagerank_2d
    from repro.obs.trace import trace_summary
    from repro.stream.delta import ingest

    assert len(jax.devices()) == 4, jax.devices()
    ND = 4
    g = powerlaw_graph(600, 5000, seed=3)
    mesh = jax.make_mesh((ND,), ("data",))
    sg = build_sharded(g, ND, d_p=8, tile=64)
    r0 = jnp.full((ND, sg.n_loc), 1.0 / g.n, jnp.float64)

    r, it = distributed_static_pagerank(mesh, sg, r0)
    rt, itt, tb = distributed_static_pagerank(mesh, sg, r0, trace=True)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rt))
    assert int(it) == int(itt)
    s = trace_summary(tb, itt)
    assert s["engine"] == "static_1d" and s["frontier"][0] == g.n

    b = random_batch(g, 0.01, seed=4)
    g2 = apply_batch(g, b)
    sg2 = build_sharded(g2, ND, d_p=8, tile=64)
    db = ingest(b, g.n).to_device()
    dv0, dn0 = initial_affected_sharded(ND, sg2.n_loc, db)
    rd, itd = distributed_dfp_pagerank(mesh, sg2, r, dv0, dn0)
    rdt, itdt, tbd = distributed_dfp_pagerank(mesh, sg2, r, dv0, dn0,
                                              trace=True)
    np.testing.assert_array_equal(np.asarray(rd), np.asarray(rdt))
    assert int(itd) == int(itdt)
    sd = trace_summary(tbd, itdt)
    assert sd["engine"] == "dfp_1d" and sd["frontier_peak"] > 0

    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    sg2d = build_sharded_2d(g, 2, 2, d_p=8)
    rc, blk = sg2d.out_deg.shape
    r0b = jnp.full((rc, blk), 1.0 / g.n, jnp.float64)
    r2, it2 = pagerank_2d(mesh2, sg2d, r0b)
    r2t, it2t, tb2 = pagerank_2d(mesh2, sg2d, r0b, trace=True)
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(r2t))
    assert int(it2) == int(it2t)
    assert trace_summary(tb2, it2t)["engine"] == "static_2d"
    print("OK")
""")


@pytest.mark.slow
def test_sharded_trace_parity_4dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         cwd=ROOT, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# -- StreamSession threading --------------------------------------------------

def test_session_trace_and_counters():
    from repro.core import BatchUpdate
    from repro.stream import StreamSession
    g = powerlaw_graph(500, 4000, seed=6)
    g_ref = powerlaw_graph(500, 4000, seed=6)
    reset_registry()
    sess = StreamSession(g, d_p=16, tile=64, trace=True)
    ref = StreamSession(g_ref, d_p=16, tile=64)
    rng = np.random.default_rng(1)
    for _ in range(2):
        s = rng.integers(0, 500, 20).astype(np.int32)
        d = rng.integers(0, 500, 20).astype(np.int32)
        ok = s != d
        b = BatchUpdate(del_src=np.zeros(0, np.int32),
                        del_dst=np.zeros(0, np.int32),
                        ins_src=s[ok], ins_dst=d[ok])
        r = sess.apply(b)
        r_ref = ref.apply(b)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r_ref))
        st = sess.history[-1]
        assert st.trace is not None
        assert st.trace["iters"] == st.iters
        assert st.trace["engine"] in ("dfp", "dfp_compact")
        assert ref.history[-1].trace is None
    rep = get_registry().report()
    assert rep["counters"]["snapshot.inplace_batches"] == 4  # 2 sessions x 2
    assert sum(v for k, v in rep["counters"].items()
               if k.startswith("session.engine.")) == 4
    for name in ("session.ingest", "session.solve",
                 "snapshot.apply_net_delta", "snapshot.device_refresh"):
        assert rep["spans"][name]["count"] >= 2, name
    reset_registry()


# -- structured sinks ---------------------------------------------------------

def test_parse_derived():
    d = parse_derived("iters=25;edges_per_s=3.5e+07;tag=abc;flag")
    assert d["iters"] == 25.0
    assert d["edges_per_s"] == 3.5e7
    assert d["tag"] == "abc"
    assert d["flag"] is True
    assert parse_derived("") == {}


def test_report_roundtrip(tmp_path):
    rep = RunReport(name="t")
    rep.add("a/b", us_min=10.0, us_mean=12.0, us_std=1.0,
            derived={"iters": 5},
            trace={"engine": "static", "iters": 2,
                   "linf_delta": [0.5, None], "frontier": [3, 3],
                   "delta_n": [0, 0], "pruned": [0, 0],
                   "frontier_peak": 3, "frontier_final": 3,
                   "linf_final": None})
    rep.add("a/c", us_min=20.0)
    reg = Registry()
    reg.inc("k", 3)
    with reg.span("s"):
        pass
    rep.attach_registry(reg)

    pj = tmp_path / "r.json"
    pl = tmp_path / "r.jsonl"
    rep.write_json(str(pj))
    rep.write_jsonl(str(pl))
    for doc in (load_report(str(pj)), load_report(str(pl))):
        assert validate_report(doc) == []
        assert [b["name"] for b in doc["benchmarks"]] == ["a/b", "a/c"]
        assert doc["benchmarks"][1]["us_mean"] == 20.0   # defaulted to min
        assert doc["counters"]["k"] == 3
        assert doc["spans"]["s"]["count"] == 1


def test_validate_report_catches_breakage():
    assert validate_report({"schema": "nope", "benchmarks": []})
    assert validate_report({"schema": "repro.obs/bench-v1",
                            "benchmarks": [{"name": "x"}]})
    bad_trace = {"schema": "repro.obs/bench-v1", "benchmarks": [
        {"name": "x", "us_min": 1.0, "us_mean": 1.0, "us_std": 0.0,
         "trace": {"engine": "static"}}]}
    assert any("trace" in e for e in validate_report(bad_trace))
    good = {"schema": "repro.obs/bench-v1", "benchmarks": [
        {"name": "x", "us_min": 1.0, "us_mean": 1.0, "us_std": 0.0}]}
    assert validate_report(good) == []


# -- the regression gate ------------------------------------------------------

def _mk_report(path, scale=1.0, drop=None):
    rep = RunReport(name="gate")
    for name, us in [("b/fast", 400.0), ("b/slow", 90000.0)]:
        if name == drop:
            continue
        rep.add(name, us_min=us * scale, us_mean=us * scale, us_std=0.0)
    rep.write_json(str(path))


def test_check_gate(tmp_path):
    from repro.obs.check import main
    base = tmp_path / "base.json"
    same = tmp_path / "same.json"
    slow = tmp_path / "slow.json"
    miss = tmp_path / "miss.json"
    _mk_report(base)
    _mk_report(same)
    _mk_report(slow, scale=1.5)
    _mk_report(miss, drop="b/slow")
    assert main([str(same), str(base)]) == 0
    assert main([str(slow), str(base)]) != 0          # injected 50% slowdown
    assert main([str(slow), str(base), "--threshold", "0.6"]) == 0
    assert main([str(miss), str(base)]) != 0          # vanished benchmark
    assert main([str(base), str(slow)]) == 0          # faster is never a fail
    # --min-us skips sub-threshold benches entirely
    assert main([str(slow), str(base), "--min-us", "1e9"]) == 0
    # missing baseline: warn-and-pass, unless --strict
    gone = str(tmp_path / "gone.json")
    assert main([str(base), gone]) == 0
    assert main([str(base), gone, "--strict"]) != 0


def test_check_cli_subprocess(tmp_path):
    _mk_report(tmp_path / "a.json")
    _mk_report(tmp_path / "b.json", scale=1.5)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs.check",
         str(tmp_path / "b.json"), str(tmp_path / "a.json")],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=120)
    assert out.returncode == 1, out.stdout + out.stderr
    out2 = subprocess.run(
        [sys.executable, "-m", "repro.obs.check",
         str(tmp_path / "a.json"), str(tmp_path / "a.json")],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=120)
    assert out2.returncode == 0, out2.stdout + out2.stderr


def test_seed_report_is_valid():
    doc = load_report(os.path.join(ROOT, "benchmarks", "seed",
                                   "BENCH_obs_seed.json"))
    assert validate_report(doc) == []
    names = {b["name"] for b in doc["benchmarks"]}
    assert any(n.startswith("static/") for n in names)
    assert any("dfp" in n for n in names)
    # the acceptance series: static + DF-P records carry iteration traces
    traces = {b["name"]: b["trace"] for b in doc["benchmarks"]
              if b.get("trace")}
    assert any(n.startswith("static/") for n in traces)
    assert any("dfp" in n for n in traces)
