import jax
import pytest

# f64 ranks (paper uses 64-bit ranks; τ = 1e-10 is below f32 resolution).
# NOTE: we intentionally do NOT set XLA_FLAGS device-count here — smoke tests
# and benches must see the real single device; only launch/dryrun.py forces 512.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
