"""Multi-device PageRank correctness (8 forced host devices, subprocess).

shard_map + all-gather pull must reproduce the single-device oracle exactly.
Runs in a subprocess because XLA fixes the device count at first init and the
rest of the suite must see 1 device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import (powerlaw_graph, random_batch, apply_batch,
                            reference_pagerank, l1_error)
    from repro.core.distributed import (build_sharded,
                                        distributed_static_pagerank,
                                        distributed_dfp_pagerank)
    assert len(jax.devices()) == 8, jax.devices()
    g = powerlaw_graph(500, 4000, seed=3)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sg = build_sharded(g, 8, d_p=8, tile=64)
    r0 = jnp.full((8, sg.n_loc), 1.0 / g.n, jnp.float64)
    r, iters = distributed_static_pagerank(mesh, sg, r0)
    ref = reference_pagerank(g)
    err = l1_error(np.asarray(r).reshape(-1)[:g.n], ref)
    assert err < 1e-8, err

    b = random_batch(g, 0.01, seed=4)
    g2 = apply_batch(g, b)
    sg2 = build_sharded(g2, 8, d_p=8, tile=64)
    n_pad = sg2.nd * sg2.n_loc
    dv = np.zeros(n_pad, bool); dn = np.zeros(n_pad, bool)
    dn[b.del_src] = True; dn[b.ins_src] = True; dv[b.del_dst] = True
    src, dst = g2.edges()
    hit = dn[src]
    dv[dst[hit]] = True
    rdfp, it2 = distributed_dfp_pagerank(
        mesh, sg2, r, jnp.asarray(dv.reshape(8, -1)),
        jnp.asarray(np.zeros((8, sg2.n_loc), bool)))
    ref2 = reference_pagerank(g2)
    err2 = l1_error(np.asarray(rdfp).reshape(-1)[:g2.n], ref2)
    assert err2 < 1e-3, err2
    # single-pod vs multi-pod style mesh must agree
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    r3, _ = distributed_static_pagerank(mesh3, sg, r0)
    np.testing.assert_allclose(np.asarray(r3), np.asarray(r), atol=1e-15)

    # delta_every=k only changes WHEN the global L-inf check runs, never the
    # fixpoint: k=4 must land on the same ranks as k=1 (within the surplus
    # iterations' contraction, far below the convergence tolerance)
    r_k4, it_k4 = distributed_static_pagerank(mesh, sg, r0, delta_every=4)
    err_k = l1_error(np.asarray(r_k4).reshape(-1)[:g.n],
                     np.asarray(r).reshape(-1)[:g.n])
    assert err_k < 1e-9, err_k
    assert int(it_k4) % 4 == 0, int(it_k4)
    print("OK")
""")


@pytest.mark.slow
def test_distributed_pagerank_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=
                         os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
