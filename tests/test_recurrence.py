"""Recurrence math: chunked RWKV-6 wkv and associative-scan RG-LRU vs naive
sequential references (beyond the decode-parity integration tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import _wkv_chunk


def _naive_wkv(r, k, v, wlog, u, s0):
    """o_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = w_t ⊙ S_{t-1} + k_t ⊗ v_t."""
    B, C, H, dk = r.shape
    s = np.asarray(s0, np.float64).copy()
    outs = np.zeros((B, C, H, dk))
    rn, kn, vn = (np.asarray(x, np.float64) for x in (r, k, v))
    wn = np.exp(np.asarray(wlog, np.float64))
    un = np.asarray(u, np.float64)
    for t in range(C):
        kv = np.einsum("bhk,bhv->bhkv", kn[:, t], vn[:, t])
        outs[:, t] = np.einsum("bhk,bhkv->bhv", rn[:, t],
                               s + un[None, :, :, None] * kv)
        s = wn[:, t][..., None] * s + kv
    return outs, s


@pytest.mark.parametrize("C,H,dk", [(4, 2, 4), (8, 3, 8), (16, 1, 16)])
def test_wkv_chunk_matches_naive_recurrence(C, H, dk, rng):
    B = 2
    r = jnp.asarray(rng.standard_normal((B, C, H, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, C, H, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, C, H, dk)), jnp.float32)
    wlog = -jnp.asarray(rng.random((B, C, H, dk)), jnp.float32) * 2.0
    u = jnp.asarray(rng.standard_normal((H, dk)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, dk, dk)), jnp.float32) * 0.1
    o, s1 = _wkv_chunk(r, k, v, wlog, u, s0)
    o_ref, s_ref = _naive_wkv(r, k, v, wlog, u, s0)
    np.testing.assert_allclose(np.asarray(o), o_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), s_ref, atol=1e-4, rtol=1e-4)


def test_rwkv_chunk_size_invariance(rng):
    """Chunk length is a tiling choice; outputs must not depend on it."""
    import dataclasses
    from repro.configs import get_config, smoke_config
    from repro.models.ssm import rwkv_init, rwkv_time_mix
    cfg8 = smoke_config(get_config("rwkv6-1.6b"))
    cfg4 = dataclasses.replace(cfg8, rec=dataclasses.replace(cfg8.rec,
                                                             chunk=4))
    p = rwkv_init(jax.random.key(0), cfg8, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg8.d_model)), jnp.float32)
    o8, (x8, s8) = rwkv_time_mix(x, p, cfg8)
    o4, (x4, s4) = rwkv_time_mix(x, p, cfg4)
    np.testing.assert_allclose(np.asarray(o8), np.asarray(o4), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s4), atol=1e-3,
                               rtol=1e-3)


def test_rglru_assoc_scan_matches_sequential(rng):
    from repro.configs import get_config, smoke_config
    from repro.models.ssm import (rglru_apply, rglru_decode, rglru_init,
                                  rglru_init_state)
    cfg = smoke_config(get_config("recurrentgemma-2b"))
    p = rglru_init(jax.random.key(1), cfg, jnp.float32)
    B, S = 2, 12
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    full, st = rglru_apply(x, p, cfg)
    st_seq = rglru_init_state(cfg, B)
    outs = []
    for t in range(S):
        o, st_seq = rglru_decode(x[:, t:t + 1], p, cfg, st_seq)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), atol=2e-5,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(st_seq["h"]),
                               atol=2e-5)
