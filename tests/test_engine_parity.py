"""Single-device vs 1-D sharded vs 2-D sharded rank parity (4 host devices).

All three engines bind the same `core.rank_step` math to different pull /
collective plumbing, so on the same graph — and, for DF-P, from the same
`initial_affected` flags — their fixpoints must agree to fp-accumulation
noise, not just to the oracle tolerance. Subprocess: XLA fixes the device
count at first init and the rest of the suite must see 1 device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import (powerlaw_graph, random_batch, apply_batch,
                            device_graph, init_ranks, static_pagerank,
                            dfp_pagerank, batch_to_device, initial_affected,
                            expand_affected, reference_pagerank, l1_error,
                            PRParams)
    from repro.core.distributed import (build_sharded,
                                        distributed_static_pagerank,
                                        distributed_dfp_pagerank,
                                        initial_affected_sharded,
                                        shard_vector, unshard_vector)
    from repro.core.distributed2d import build_sharded_2d, pagerank_2d, dfp_2d
    from repro.core.dynamic import DeviceBatch

    assert len(jax.devices()) == 4, jax.devices()
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    g = powerlaw_graph(600, 6000, seed=11)
    n = g.n
    params = PRParams(tau_f=1e-9, tau_p=1e-9)

    # --- static parity ----------------------------------------------------
    dg = device_graph(g, d_p=8, tile=64)
    r_sd, _ = static_pagerank(dg, init_ranks(n), params)
    r_sd = np.asarray(r_sd)

    sg1 = build_sharded(g, 4, d_p=8, tile=64)
    r0 = jnp.full((4, sg1.n_loc), 1.0 / n, jnp.float64)
    r_1d, _ = distributed_static_pagerank(mesh, sg1, r0, params)
    r_1d = unshard_vector(r_1d, n)

    sg2 = build_sharded_2d(g, 2, 2, d_p=8)
    rc, blk = sg2.out_deg.shape
    r_2d, _ = pagerank_2d(mesh, sg2,
                          jnp.full((rc, blk), 1.0 / n, jnp.float64), params)
    r_2d = np.asarray(r_2d).reshape(-1)[:n]

    ref = reference_pagerank(g)
    for name, r in (("single", r_sd), ("1d", r_1d), ("2d", r_2d)):
        err = l1_error(r, ref)
        assert err < 1e-8, (name, err)
    assert l1_error(r_1d, r_sd) < 1e-9, l1_error(r_1d, r_sd)
    assert l1_error(r_2d, r_sd) < 1e-9, l1_error(r_2d, r_sd)

    # --- DF-P parity from the SAME initial_affected flags -----------------
    b = random_batch(g, 0.01, seed=12)
    g2 = apply_batch(g, b)
    db = batch_to_device(b, n)
    dv0, dn0 = initial_affected(n, db.del_src, db.del_dst, db.ins_src)

    dg2 = device_graph(g2, d_p=8, tile=64)
    r_dfp_sd, _ = dfp_pagerank(dg2, jnp.asarray(r_sd), db, params)
    r_dfp_sd = np.asarray(r_dfp_sd)

    sg1b = build_sharded(g2, 4, d_p=8, tile=64)
    # stacked flags from the same dense flag vectors (engine expands at i=0)
    dv_s = shard_vector(np.asarray(dv0), 4, fill=False)
    dn_s = shard_vector(np.asarray(dn0), 4, fill=False)
    r_dfp_1d, _ = distributed_dfp_pagerank(
        mesh, sg1b, jnp.asarray(shard_vector(r_sd, 4, fill=1.0 / n)),
        dv_s, dn_s, params)
    r_dfp_1d = unshard_vector(r_dfp_1d, n)

    sg2b = build_sharded_2d(g2, 2, 2, d_p=8)
    rc, blk = sg2b.out_deg.shape
    pad2 = rc * blk - n
    r_prev2 = jnp.asarray(np.concatenate(
        [r_sd, np.full(pad2, 1.0 / n)]).reshape(rc, blk))
    dv2 = jnp.asarray(np.concatenate(
        [np.asarray(dv0), np.zeros(pad2, bool)]).reshape(rc, blk))
    dn2 = jnp.asarray(np.concatenate(
        [np.asarray(dn0), np.zeros(pad2, bool)]).reshape(rc, blk))
    r_dfp_2d, _ = dfp_2d(mesh, sg2b, r_prev2, dv2, dn2, params)
    r_dfp_2d = np.asarray(r_dfp_2d).reshape(-1)[:n]

    ref2 = reference_pagerank(g2)
    for name, r in (("single", r_dfp_sd), ("1d", r_dfp_1d),
                    ("2d", r_dfp_2d)):
        err = l1_error(r, ref2)
        assert err < 1e-7, (name, err)
    assert l1_error(r_dfp_1d, r_dfp_sd) < 1e-8, l1_error(r_dfp_1d, r_dfp_sd)
    assert l1_error(r_dfp_2d, r_dfp_sd) < 1e-8, l1_error(r_dfp_2d, r_dfp_sd)
    print("OK")
""")


@pytest.mark.slow
def test_engine_parity_4dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
