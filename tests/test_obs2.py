"""repro.obs v2 (ISSUE 10): flight recorder, latency histograms + SLOs,
machine-readable regression verdicts, and post-mortem bundles.

Invariants under test:

  * the flight ring is bounded, thread-safe, and exact about what it
    dropped — seq numbers never lie, even under concurrent writers;
  * histograms report percentiles within their documented relative error
    and the span registry surfaces them in ``report()``;
  * the bench report schema bump (v1 -> v2) is backward compatible in both
    the loader and the gate, and the gate fails on p99-only regressions;
  * ``guard.health`` decode strings are stable (operators grep for them);
  * an SLO breach arms profiler capture around the next batches;
  * escalation-ladder exhaustion and restore failure each produce a bundle
    that ``python -m repro.obs.postmortem`` renders.
"""
import json
import os
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

import repro.stream.session as session_mod
from repro.core.graph import random_batch, random_graph
from repro.guard import (ChaosMonkey, GuardConfig, H_MASS_DRIFT, H_MAX_ITER,
                         H_NONFINITE, describe_health, health_flags)
from repro.obs import (FlightRecorder, Histogram, RunReport, SLOConfig,
                       get_flight, load_bundle, load_report, obs_enabled,
                       reset_flight, set_obs_enabled, validate_report,
                       write_bundle)
from repro.obs import postmortem
from repro.obs.check import main as check_main
from repro.obs.report import SCHEMA, SCHEMA_V1
from repro.obs.spans import get_registry, reset_registry
from repro.stream import StreamSession

N, M = 512, 4096


@pytest.fixture()
def g():
    return random_graph(N, M, seed=0)


@pytest.fixture(autouse=True)
def _fresh_obs():
    reset_registry()
    reset_flight()
    set_obs_enabled(True)
    yield
    reset_registry()
    reset_flight()
    set_obs_enabled(True)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_ring_wraparound():
    fl = FlightRecorder(capacity=8)
    for i in range(20):
        fl.emit("tick", i=i)
    assert len(fl) == 8
    assert fl.total == 20
    assert fl.dropped == 12
    evs = fl.events()
    assert [e.seq for e in evs] == list(range(12, 20))  # newest window
    assert [e.data["i"] for e in evs] == list(range(12, 20))
    assert [e.seq for e in fl.tail(3)] == [17, 18, 19]
    s = fl.summary()
    assert s == {"total": 20, "dropped": 12, "capacity": 8,
                 "by_kind": {"tick": 20}}


def test_flight_concurrent_writers():
    """Wraparound under concurrent emits: no lost counts, no duplicate or
    out-of-order seq numbers in the surviving window."""
    fl = FlightRecorder(capacity=64)
    threads, per = 8, 200

    def writer(t):
        for i in range(per):
            fl.emit(f"kind{t}", i=i)

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert fl.total == threads * per
    assert fl.dropped == threads * per - 64
    seqs = [e.seq for e in fl.events()]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs) == 64
    assert sum(fl.summary()["by_kind"].values()) == threads * per


def test_obs_enabled_toggle():
    fl = FlightRecorder()
    set_obs_enabled(False)
    assert not obs_enabled()
    fl.emit("tick")
    assert fl.total == 0
    with get_registry().span("toggle.span"):
        pass
    assert get_registry().span_hist("toggle.span") is None  # hists gated
    assert get_registry().span_stats("toggle.span").count == 1  # spans not
    set_obs_enabled(True)
    fl.emit("tick")
    assert fl.total == 1


# ---------------------------------------------------------------------------
# histograms + registry percentiles
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_bucket_error():
    h = Histogram()
    vals = [i / 1000.0 for i in range(1, 1001)]  # 1ms..1s uniform
    for v in vals:
        h.add(v)
    assert h.count == 1000
    # log-bucketed: <= ~6.6% relative error at 36 buckets/decade, and the
    # report is clamped to the observed range
    assert h.percentile(50) == pytest.approx(0.5, rel=0.08)
    assert h.percentile(99) == pytest.approx(0.99, rel=0.08)
    assert h.percentile(100) == 1.0
    d = h.as_dict()
    assert d["count"] == 1000 and d["max_s"] == 1.0
    assert d["p50_s"] <= d["p95_s"] <= d["p99_s"] <= d["max_s"]


def test_histogram_empty_and_garbage():
    h = Histogram()
    assert h.percentile(99) is None
    assert h.as_dict() == {"count": 0}
    h.add(float("nan"))
    h.add(-1.0)
    assert h.count == 0  # not latencies


def test_histogram_merge():
    a, b = Histogram(), Histogram()
    for v in (0.001, 0.002):
        a.add(v)
    for v in (0.004, 0.008):
        b.add(v)
    a.merge(b)
    assert a.count == 4
    assert a.max == 0.008
    assert a.percentile(100) == 0.008


def test_registry_spans_carry_percentiles():
    reg = get_registry()
    for _ in range(10):
        with reg.span("pct.work"):
            pass
    rep = reg.report()["spans"]["pct.work"]
    assert rep["count"] == 10
    for k in ("p50_s", "p95_s", "p99_s"):
        assert isinstance(rep[k], float) and rep[k] >= 0.0
    assert rep["p50_s"] <= rep["p99_s"] <= rep["max_s"] * 1.07  # bucket slack


# ---------------------------------------------------------------------------
# report schema v2 + the check gate
# ---------------------------------------------------------------------------

def test_report_v2_roundtrip_with_flight(tmp_path):
    get_flight().emit("roundtrip", n=1)
    rep = RunReport(name="t")
    rep.add("x/one", us_min=10.0, us_mean=12.0, us_p50=11.0, us_p95=14.0,
            us_p99=15.0, us_max=16.0)
    rep.add("x/two", us_min=5.0)  # percentiles optional per record
    rep.attach_registry()
    rep.attach_flight()
    p = tmp_path / "r.json"
    rep.write_json(str(p))
    doc = load_report(str(p))
    assert doc["schema"] == SCHEMA
    assert validate_report(doc) == []
    assert doc["flight"]["by_kind"]["roundtrip"] == 1
    one = next(b for b in doc["benchmarks"] if b["name"] == "x/one")
    assert one["us_p99"] == 15.0
    assert "us_p99" not in next(b for b in doc["benchmarks"]
                                if b["name"] == "x/two")


def test_report_v1_still_validates():
    doc = {"schema": SCHEMA_V1, "name": "old", "benchmarks": [
        {"name": "a", "us_min": 1.0, "us_mean": 1.0, "us_std": 0.0}]}
    assert validate_report(doc) == []
    assert validate_report({"schema": "nope", "benchmarks": []})


def _write_report(path, rows, schema=SCHEMA):
    doc = {"schema": schema, "name": "t", "created_unix": 0.0, "env": {},
           "spans": {}, "counters": {}, "flight": {},
           "benchmarks": [
               {"name": n, "us_min": m, "us_mean": m, "us_std": 0.0,
                **extra} for n, m, extra in rows]}
    Path(path).write_text(json.dumps(doc))


def test_check_gates_p99_only_regression(tmp_path, capsys):
    """Mean holds, tail doubles: v2 gate must fail — and say so in the
    --json verdict document."""
    base, cur = tmp_path / "base.json", tmp_path / "cur.json"
    _write_report(base, [("s/a", 100.0, {"us_p99": 200.0})])
    _write_report(cur, [("s/a", 100.0, {"us_p99": 400.0})])
    rc = check_main([str(cur), str(base), "--threshold", "0.5"])
    out = capsys.readouterr().out
    assert rc == 1 and "p99" in out
    rc = check_main([str(cur), str(base), "--threshold", "0.5", "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 1 and doc["verdict"] == "fail"
    assert any("p99" in f for f in doc["failures"])
    (rec,) = doc["benchmarks"]
    assert rec["status"] == "regression" and rec["p99_ratio"] == 2.0
    # identical tails pass (and the verdict says so)
    _write_report(cur, [("s/a", 100.0, {"us_p99": 200.0})])
    rc = check_main([str(cur), str(base), "--threshold", "0.5", "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0 and doc["verdict"] == "pass" and doc["failures"] == []


def test_check_v1_baseline_no_p99_gate(tmp_path, capsys):
    """v2 current vs v1 baseline: percentile columns absent on one side are
    simply not gated (the compat contract)."""
    base, cur = tmp_path / "base.json", tmp_path / "cur.json"
    _write_report(base, [("s/a", 100.0, {})], schema=SCHEMA_V1)
    _write_report(cur, [("s/a", 100.0, {"us_p99": 9999.0})])
    assert check_main([str(cur), str(base), "--threshold", "0.1"]) == 0
    capsys.readouterr()


def test_seed_report_is_v2_with_percentiles():
    seed = (Path(__file__).resolve().parents[1] / "benchmarks" / "seed"
            / "BENCH_obs_seed.json")
    doc = load_report(str(seed))
    assert doc["schema"] == SCHEMA
    assert validate_report(doc) == []
    assert any("us_p99" in b for b in doc["benchmarks"])


# ---------------------------------------------------------------------------
# health decode strings (operators grep for these)
# ---------------------------------------------------------------------------

def test_health_decode_strings_are_stable():
    assert describe_health(0) == "ok"
    assert describe_health(H_MAX_ITER) == "max_iter"
    assert describe_health(H_NONFINITE) == "nonfinite"
    assert describe_health(H_MASS_DRIFT) == "mass_drift"
    assert describe_health(H_MAX_ITER | H_NONFINITE) == "max_iter+nonfinite"
    assert describe_health(H_MAX_ITER | H_NONFINITE | H_MASS_DRIFT) == \
        "max_iter+nonfinite+mass_drift"
    assert health_flags(0) == ()
    assert health_flags(H_NONFINITE | H_MASS_DRIFT) == ("nonfinite",
                                                        "mass_drift")


# ---------------------------------------------------------------------------
# SLO breach -> profiler capture
# ---------------------------------------------------------------------------

def test_slo_breach_arms_profiler_capture(g, monkeypatch):
    calls = {"start": [], "stop": 0}
    monkeypatch.setattr(session_mod, "start_profiler",
                        lambda d: calls["start"].append(d) or True)
    monkeypatch.setattr(session_mod, "stop_profiler",
                        lambda: calls.__setitem__("stop", calls["stop"] + 1)
                        or True)
    sess = StreamSession(g, slo=SLOConfig(solve_p99_us=0.0, min_samples=1,
                                          capture_batches=2,
                                          capture_dir="ignored-dir"))
    for seed in range(4):
        sess.apply(random_batch(g, 16, seed=seed))
    obs = get_registry()
    assert obs.counter("slo.breach.solve_p99") >= 1
    # one auto-arm per session: exactly one start/stop pair spanning the
    # two batches after the first breach
    assert calls["start"] == ["ignored-dir"]
    assert calls["stop"] == 1
    assert obs.counter("slo.capture.start") == 1
    assert obs.counter("slo.capture.stop") == 1
    kinds = [e.kind for e in get_flight().events()]
    assert "slo.breach" in kinds
    assert "slo.capture.start" in kinds and "slo.capture.stop" in kinds
    # p99 visible to callers
    pct = sess.solve_percentiles()
    assert pct["count"] == 4 and pct["p99_s"] > 0


def test_slo_quiet_when_under_budget(g):
    sess = StreamSession(g, slo=SLOConfig(solve_p99_us=1e12, min_samples=1))
    sess.apply(random_batch(g, 16, seed=1))
    assert get_registry().counter("slo.breach.solve_p99") == 0


def test_arm_capture_manual(g, monkeypatch):
    started = []
    monkeypatch.setattr(session_mod, "start_profiler",
                        lambda d: started.append(d) or True)
    monkeypatch.setattr(session_mod, "stop_profiler", lambda: True)
    sess = StreamSession(g)
    sess.arm_capture(1, log_dir="manual-dir")
    sess.apply(random_batch(g, 16, seed=2))
    assert started == ["manual-dir"]


# ---------------------------------------------------------------------------
# post-mortem bundles
# ---------------------------------------------------------------------------

@pytest.mark.guard
def test_exhaustion_writes_renderable_bundle(g, tmp_path, capsys):
    """The acceptance path: chaos-forced ladder exhaustion produces a bundle
    that the CLI renders."""
    sess = StreamSession(g, guard=GuardConfig(
        retry_budget=0, postmortem_dir=str(tmp_path)))
    sess.ranks = ChaosMonkey(seed=9).poison_ranks(sess.ranks, mode="nan",
                                                  k=1, idx=[7])
    sess.apply(random_batch(g, 32, seed=14))
    assert get_registry().counter("guard.escalate.exhausted") == 1

    bundles = sorted(tmp_path.glob("postmortem-*"))
    assert len(bundles) == 1
    doc = load_bundle(str(bundles[0]))
    assert doc["schema"] == postmortem.SCHEMA
    assert doc["reason"] == "escalation_exhausted"
    assert "nonfinite" in doc["health"]["flags"]
    assert doc["journal_seq"] == 1
    assert doc["extra"]["rungs_walked"] == 0
    assert (bundles[0] / "flight.jsonl").exists()
    kinds = {json.loads(line)["kind"]
             for line in (bundles[0] / "flight.jsonl").read_text().splitlines()}
    assert "session.engine" in kinds
    assert "guard.escalate.exhausted" in kinds

    # renders in-process (newest-bundle resolution from the parent dir)...
    assert postmortem.main([str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "escalation_exhausted" in out and "nonfinite" in out
    assert "guard.escalate.exhausted" in out
    # ...and through the real CLI entry point
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.postmortem", str(bundles[0])],
        capture_output=True, text=True,
        cwd=str(Path(__file__).resolve().parents[1]),
        env={**os.environ,
             "PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src")})
    assert proc.returncode == 0, proc.stderr
    assert "escalation_exhausted" in proc.stdout


@pytest.mark.guard
def test_restore_failure_writes_bundle(tmp_path):
    with pytest.raises(Exception):
        StreamSession.restore(str(tmp_path))  # nothing there to restore
    bundles = sorted(tmp_path.glob("postmortem-*"))
    assert len(bundles) == 1
    doc = load_bundle(str(bundles[0]))
    assert doc["reason"] == "restore_failed"
    assert "error" in doc["extra"]


def test_write_bundle_never_raises(tmp_path):
    # unwritable parent: swallowed, None returned, failure counted
    assert write_bundle(str(tmp_path / "nope\0bad"), reason="x") is None
    assert get_registry().counter("postmortem.failed") == 1


def test_bundle_embeds_registry_and_quarantine(tmp_path):
    get_registry().inc("some.counter", 3)
    path = write_bundle(str(tmp_path), reason="manual",
                        health=H_MAX_ITER,
                        quarantine={"size": 4},
                        journal_seq=17)
    assert path is not None
    doc = load_bundle(path)
    assert doc["health"]["describe"] == "max_iter"
    assert doc["quarantine"] == {"size": 4}
    assert doc["journal_seq"] == 17
    assert doc["registry"]["counters"]["some.counter"] == 3
