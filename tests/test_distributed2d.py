"""2-D edge-partitioned PageRank vs 1-D engine and oracle (4 host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import (powerlaw_graph, random_batch, apply_batch,
                            reference_pagerank, l1_error)
    from repro.core.distributed2d import (build_sharded_2d, pagerank_2d,
                                          dfp_2d)
    assert len(jax.devices()) == 4
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    g = powerlaw_graph(500, 4000, seed=3)
    sg = build_sharded_2d(g, 2, 2, d_p=8)
    rc, blk = sg.out_deg.shape
    r0 = jnp.full((rc, blk), 1.0 / g.n, jnp.float64)
    r, iters = pagerank_2d(mesh, sg, r0)
    ref = reference_pagerank(g)
    err = l1_error(np.asarray(r).reshape(-1)[:g.n], ref)
    assert err < 1e-8, err

    b = random_batch(g, 0.01, seed=4)
    g2 = apply_batch(g, b)
    sg2 = build_sharded_2d(g2, 2, 2, d_p=8)
    n_pad = rc * blk
    dv = np.zeros(n_pad, bool); dn = np.zeros(n_pad, bool)
    dn[b.del_src] = True; dn[b.ins_src] = True; dv[b.del_dst] = True
    src, dst = g2.edges(); dv[dst[dn[src]]] = True
    r2, it2 = dfp_2d(mesh, sg2, r, jnp.asarray(dv.reshape(rc, -1)),
                     jnp.asarray(np.zeros((rc, blk), bool)))
    err2 = l1_error(np.asarray(r2).reshape(-1)[:g2.n],
                    reference_pagerank(g2))
    assert err2 < 1e-3, err2
    print("OK")
""")


@pytest.mark.slow
def test_2d_pagerank_matches_oracle_4dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
