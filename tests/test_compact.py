"""Frontier-compacted DF/DF-P: equivalence with the dense engine, capacity
overflow fallback, and the work-reduction property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (apply_batch, batch_to_device, device_graph,
                        df_pagerank, df_pagerank_compact, dfp_pagerank,
                        dfp_pagerank_compact, forward_device_graph,
                        init_ranks, l1_error, powerlaw_graph, random_batch,
                        random_graph, reference_pagerank, static_pagerank)
from repro.core.compact import _compact_loop, _scatter_expand
from repro.core.frontier import expand_affected, initial_affected
from repro.core.pagerank import PRParams

CAPS = dict(d_p=16, tile=64)


def _setup(n=2000, m=20000, frac=1e-3, seed=3):
    g = powerlaw_graph(n, m, seed=seed)
    dg = device_graph(g, **CAPS)
    fwd = forward_device_graph(g, **CAPS)
    r_prev, _ = static_pagerank(dg, init_ranks(g.n))
    b = random_batch(g, frac, seed=seed + 2)
    g2 = apply_batch(g, b)
    dg2 = device_graph(g2, **CAPS)
    fwd2 = forward_device_graph(g2, **CAPS)
    db = batch_to_device(b, g.n)
    return g2, dg2, fwd2, r_prev, db


def test_scatter_expand_matches_dense_pull():
    g2, dg2, fwd2, r_prev, db = _setup()
    n = dg2.n
    dv, dn = initial_affected(n, db.del_src, db.del_dst, db.ins_src)
    dense = expand_affected(dg2, dv, dn)
    compact = dv | _scatter_expand(fwd2, dn, n)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(compact))


@pytest.mark.parametrize("prune", [True, False])
def test_compact_loop_matches_dense_at_full_capacity(prune):
    from repro.core.dynamic import _loop
    g2, dg2, fwd2, r_prev, db = _setup()
    n = dg2.n
    dv, dn = initial_affected(n, db.del_src, db.del_dst, db.ins_src)
    dv = expand_affected(dg2, dv, dn)
    off = jnp.zeros(n, bool)
    p = PRParams(max_iter=6)
    r_d, _ = jax.jit(lambda: _loop(dg2, r_prev, dv, off, p, expand=True,
                                   prune=prune, closed_form=prune))()
    r_c, *_ = _compact_loop(dg2, fwd2, r_prev, dv, off, p, n,
                            dg2.hi_tiles.shape[0], n, prune)
    np.testing.assert_allclose(np.asarray(r_d), np.asarray(r_c), atol=1e-15)


@pytest.mark.parametrize("frac", [1e-4, 1e-3, 1e-2])
def test_compact_dfp_correct_across_batch_sizes(frac):
    g2, dg2, fwd2, r_prev, db = _setup(frac=frac)
    ref = reference_pagerank(g2)
    r, iters = dfp_pagerank_compact(dg2, fwd2, r_prev, db)
    assert l1_error(np.asarray(r), ref) < 1e-3
    assert int(iters) > 0


def test_compact_df_correct():
    g2, dg2, fwd2, r_prev, db = _setup()
    ref = reference_pagerank(g2)
    r, _ = df_pagerank_compact(dg2, fwd2, r_prev, db)
    assert l1_error(np.asarray(r), ref) < 1e-5


def test_overflow_falls_back_to_dense():
    """A huge batch overflows any reasonable capacity; results must still be
    correct because the dense engine finishes the job."""
    g2, dg2, fwd2, r_prev, db = _setup(frac=0.2)
    ref = reference_pagerank(g2)
    r, iters = dfp_pagerank_compact(dg2, fwd2, r_prev, db)
    assert l1_error(np.asarray(r), ref) < 1e-2
