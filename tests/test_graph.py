"""Unit tests: host graph construction, batches, hybrid layout (Alg. 4)."""
import numpy as np
import pytest

from repro.core import (BatchUpdate, apply_batch, build_graph, build_hybrid,
                        powerlaw_graph, random_batch, random_graph,
                        temporal_stream)
from repro.core.partition import partition_by_degree, partition_by_degree_jax


def test_self_loops_no_dead_ends():
    g = random_graph(100, 300, seed=0)
    assert np.all(g.out_degree() >= 1)
    for v in (0, 17, 99):
        assert g.has_edge(v, v)


def test_transpose_consistency():
    g = random_graph(200, 1000, seed=1)
    src, dst = g.edges()
    # rebuild in-degree from forward edges
    indeg = np.bincount(dst, minlength=g.n)
    assert np.array_equal(indeg, g.in_degree())
    assert g.m == g.targets.shape[0] == g.t_sources.shape[0]


def test_apply_batch_insert_delete():
    g = random_graph(50, 200, seed=2)
    b = random_batch(g, 0.1, seed=3)
    g2 = apply_batch(g, b)
    for u, v in zip(b.ins_src, b.ins_dst):
        assert g2.has_edge(int(u), int(v))
    for u, v in zip(b.del_src, b.del_dst):
        if int(u) != int(v) and not np.any((b.ins_src == u) & (b.ins_dst == v)):
            assert not g2.has_edge(int(u), int(v))
    assert np.all(g2.out_degree() >= 1)  # self-loops survive


def test_batch_mix_ratio():
    g = random_graph(300, 5000, seed=4)
    b = random_batch(g, 0.01, insert_frac=0.8, seed=5)
    assert b.ins_src.shape[0] == round(0.8 * round(0.01 * g.m))


def test_partition_matches_alg4_semantics():
    g = powerlaw_graph(500, 4000, seed=6)
    indeg = g.in_degree()
    perm, n_low = partition_by_degree(indeg, 16)
    assert sorted(perm.tolist()) == list(range(g.n))  # a permutation
    assert np.all(indeg[perm[:n_low]] <= 16)
    assert np.all(indeg[perm[n_low:]] > 16)
    # stability (paper's scan keeps id order within each side)
    assert np.all(np.diff(perm[:n_low]) > 0)
    assert np.all(np.diff(perm[n_low:]) > 0)


def test_partition_jax_matches_numpy():
    g = powerlaw_graph(300, 2500, seed=7)
    indeg = g.in_degree()
    perm_np, n_low_np = partition_by_degree(indeg, 8)
    perm_j, n_low_j = partition_by_degree_jax(indeg, 8)
    assert int(n_low_j) == n_low_np
    assert np.array_equal(np.asarray(perm_j), perm_np)


def test_hybrid_layout_covers_all_edges():
    g = powerlaw_graph(400, 3000, seed=8)
    lay = build_hybrid(g, d_p=8, tile=32)
    # total real edges across ELL buckets + tiles equals |E|
    total = int(sum(b.mask.sum() for b in lay.buckets) + lay.hi_tmask.sum())
    assert total == g.m
    # high-degree vertices live on no bucket (CSR-side sentinel)
    hi = np.nonzero(~lay.is_low)[0]
    assert (lay.bucket_of[hi] == len(lay.widths)).all()
    # every high vertex id appears once in hi_ids
    assert set(lay.hi_ids[lay.hi_ids < g.n].tolist()) == set(hi.tolist())
    # every low vertex sits in the narrowest bucket that fits its degree,
    # at a slot whose row-id map points back at it
    indeg = g.in_degree()
    widths = np.asarray(lay.widths)
    low = np.nonzero(lay.is_low)[0]
    want = np.searchsorted(widths, np.maximum(indeg[low], 1), side="left")
    assert np.array_equal(lay.bucket_of[low], want)
    for v in low[:50]:
        blk = lay.buckets[lay.bucket_of[v]]
        assert blk.rows[lay.slot_of[v]] == v


def test_hybrid_capacity_padding():
    g = powerlaw_graph(200, 1500, seed=9)
    lay0 = build_hybrid(g, d_p=8, tile=32)
    lay = build_hybrid(g, d_p=8, tile=32,
                       n_hi_cap=lay0.n_hi_cap + 7,
                       t_cap=lay0.hi_tiles.shape[0] + 5)
    assert lay.hi_ids.shape[0] == lay0.n_hi_cap + 7
    assert int(lay.hi_tmask.sum()) == int(lay0.hi_tmask.sum())


def test_hybrid_caps_rebuilds_at_stable_shapes():
    """hybrid_caps(lay) is the capacity signature: rebuilding a mutated
    snapshot with it must reproduce identical device shapes (the no-recompile
    contract the dynamic/stream engines rely on)."""
    from repro.core import hybrid_caps
    g = powerlaw_graph(300, 2500, seed=10)
    caps = hybrid_caps(build_hybrid(g, d_p=8, tile=32,
                                    n_hi_cap=64, t_cap=128))
    # default bucket caps are exact counts; a dynamic holder adds headroom
    caps["bucket_caps"] = tuple(2 * c for c in caps["bucket_caps"])
    lay0 = build_hybrid(g, **caps)
    g2 = apply_batch(g, random_batch(g, 0.01, seed=11))
    lay2 = build_hybrid(g2, **hybrid_caps(lay0))
    assert lay2.widths == lay0.widths
    for b0, b2 in zip(lay0.buckets, lay2.buckets):
        assert b2.idx.shape == b0.idx.shape
    assert lay2.hi_ids.shape == lay0.hi_ids.shape
    assert lay2.hi_tiles.shape == lay0.hi_tiles.shape
    assert (lay2.d_p, lay2.tile) == (lay0.d_p, lay0.tile)


def test_temporal_stream_protocol():
    base, batches = temporal_stream(100, 2000, n_batches=10, seed=10)
    assert len(batches) == 10
    assert all(b.del_src.size == 0 for b in batches)  # insertion-only stream
    assert base.m >= 100  # self-loops at minimum


def test_build_hybrid_rows_matches_build_hybrid():
    from repro.core import build_hybrid_rows
    g = powerlaw_graph(300, 3000, seed=5)
    lay = build_hybrid(g, d_p=8, tile=32)
    hr = build_hybrid_rows(g.t_offsets, g.t_sources, d_p=8, tile=32)
    assert lay.widths == hr.widths
    for b1, b2 in zip(lay.buckets, hr.buckets):
        assert np.array_equal(b1.rows, b2.rows)
        assert np.array_equal(b1.idx, b2.idx)
        assert np.array_equal(b1.mask, b2.mask)
    for f in ("bucket_of", "slot_of", "hi_ids", "hi_tiles", "hi_tmask",
              "hi_rowmap", "is_low"):
        assert np.array_equal(getattr(lay, f), getattr(hr, f)), f
    assert np.array_equal(hr.row_deg, g.in_degree())
    # padded empty rows: parked in bucket 0 (degree 0, fully masked), no
    # real slots disturbed
    hr2 = build_hybrid_rows(g.t_offsets, g.t_sources, d_p=8, tile=32,
                            n_rows=g.n + 7)
    assert hr2.is_low[g.n:].all() and (hr2.bucket_of[g.n:] == 0).all()
    assert int(sum(b.mask.sum() for b in hr2.buckets)) == \
        int(sum(b.mask.sum() for b in hr.buckets))


def test_build_sharded_trailing_empty_shard():
    # nd=8, n=10 -> n_loc=2 and shards 5..7 are fully past the real vertex
    # range; the clamped shard_bounds must keep them as pure padding
    from repro.core.distributed import build_sharded, shard_bounds
    g = powerlaw_graph(10, 40, seed=0)
    sg = build_sharded(g, 8, d_p=4, tile=16)
    assert sg.n_loc * sg.nd >= g.n
    assert shard_bounds(6, sg.n_loc, g.n) == (10, 10)
    valid = np.asarray(sg.valid)
    assert valid.sum() == g.n and not valid[5:].any()
