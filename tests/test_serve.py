"""Serving loop: batched prefill + greedy decode on a smoke config."""
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.serve import serve


def test_serve_generates_tokens():
    cfg = smoke_config(get_config("smollm-360m"))
    toks, tps = serve(cfg, batch=2, prompt_len=8, gen=6)
    assert toks.shape == (2, 6)
    assert toks.min() >= 0 and toks.max() < cfg.vocab
    assert tps > 0


def test_serve_deterministic():
    cfg = smoke_config(get_config("qwen3-4b"))
    a, _ = serve(cfg, batch=2, prompt_len=8, gen=4, seed=3)
    b, _ = serve(cfg, batch=2, prompt_len=8, gen=4, seed=3)
    np.testing.assert_array_equal(a, b)
