"""Integration tests: Static / ND / DT / DF / DF-P vs the numpy oracle.

Checks the paper's correctness claims: all approaches converge to the
reference ranks; error ordering Static >= DF-P >= {DF, DT, ND} holds at the
default tolerances; DF-P touches (far) fewer vertices than Static.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PRParams, apply_batch, batch_to_device, device_graph,
                        df_pagerank, dfp_pagerank, dt_pagerank, init_ranks,
                        l1_error, nd_pagerank, powerlaw_graph, random_batch,
                        random_graph, reference_pagerank, static_pagerank,
                        update_ranks)
from repro.core.reference import numpy_pagerank


@pytest.mark.parametrize("maker,n,m", [
    (random_graph, 400, 2500),
    (powerlaw_graph, 400, 2500),
])
def test_static_matches_numpy_oracle(maker, n, m):
    g = maker(n, m, seed=1)
    dg = device_graph(g, d_p=8, tile=64)
    r, iters = static_pagerank(dg, init_ranks(g.n))
    r_np, it_np = numpy_pagerank(g, tau=1e-10)
    assert int(iters) == it_np
    np.testing.assert_allclose(np.asarray(r), r_np, rtol=0, atol=1e-14)


def test_static_rank_sum_is_one():
    g = powerlaw_graph(600, 5000, seed=2)
    dg = device_graph(g)
    r, _ = static_pagerank(dg, init_ranks(g.n))
    assert abs(float(r.sum()) - 1.0) < 1e-9


def test_dp_threshold_invariance():
    """Partitioning is a performance choice; results must be identical."""
    g = powerlaw_graph(300, 3000, seed=3)
    rs = []
    for d_p in (2, 8, 64):
        dg = device_graph(g, d_p=d_p, tile=32)
        r, _ = static_pagerank(dg, init_ranks(g.n))
        rs.append(np.asarray(r))
    np.testing.assert_allclose(rs[0], rs[1], atol=1e-15)
    np.testing.assert_allclose(rs[0], rs[2], atol=1e-15)


def _dynamic_setup(n=400, m=3000, frac=0.01, seed=4):
    g = random_graph(n, m, seed=seed)
    dg = device_graph(g, d_p=8, tile=64)
    r_prev, _ = static_pagerank(dg, init_ranks(g.n))
    b = random_batch(g, frac, seed=seed + 1)
    g2 = apply_batch(g, b)
    caps = dict(d_p=8, tile=64)
    dg2 = device_graph(g2, **caps)
    db = batch_to_device(b, g.n)
    ref = reference_pagerank(g2)
    return g, g2, dg, dg2, r_prev, db, ref


def test_all_dynamic_approaches_converge_to_reference():
    g, g2, dg, dg2, r_prev, db, ref = _dynamic_setup()
    r_nd, _ = nd_pagerank(dg2, r_prev)
    r_dt, _ = dt_pagerank(dg2, dg, r_prev, db)
    r_df, _ = df_pagerank(dg2, r_prev, db)
    r_dfp, _ = dfp_pagerank(dg2, r_prev, db)
    for name, rr, tol in [("ND", r_nd, 1e-6), ("DT", r_dt, 1e-6),
                          ("DF", r_df, 1e-6), ("DFP", r_dfp, 1e-3)]:
        err = l1_error(np.asarray(rr), ref)
        assert err < tol, (name, err)


def test_error_ordering_matches_paper():
    """Paper Fig. 3(b)/5: err(DF-P) >= err(DF) >= err(ND); all << err(Static
    stopped at the same τ) is not claimed — but DF-P must stay acceptable."""
    _, _, dg, dg2, r_prev, db, ref = _dynamic_setup(seed=7)
    e = {}
    e["nd"] = l1_error(np.asarray(nd_pagerank(dg2, r_prev)[0]), ref)
    e["df"] = l1_error(np.asarray(df_pagerank(dg2, r_prev, db)[0]), ref)
    e["dfp"] = l1_error(np.asarray(dfp_pagerank(dg2, r_prev, db)[0]), ref)
    assert e["dfp"] >= e["df"] - 1e-12
    assert e["df"] >= e["nd"] - 1e-12
    assert e["dfp"] < 1e-3


def test_dfp_work_reduction():
    """DF-P must touch far fewer vertices than |V| for a small batch."""
    import jax
    from repro.core.dynamic import DeviceBatch
    from repro.core.frontier import expand_affected, initial_affected

    g, g2, dg, dg2, r_prev, db, ref = _dynamic_setup(frac=0.001, seed=9)
    dv, dn = initial_affected(dg2.n, db.del_src, db.del_dst, db.ins_src)
    dv = expand_affected(dg2, dv, dn)
    assert int(dv.sum()) < 0.2 * dg2.n


def test_empty_batch_is_noop():
    g = random_graph(200, 1000, seed=11)
    dg = device_graph(g, d_p=8, tile=64)
    r_prev, _ = static_pagerank(dg, init_ranks(g.n))
    db = batch_to_device(
        type("B", (), {"del_src": np.zeros(0, np.int32),
                       "del_dst": np.zeros(0, np.int32),
                       "ins_src": np.zeros(0, np.int32),
                       "ins_dst": np.zeros(0, np.int32)})(), g.n, pad_to=4)
    r_dfp, iters = dfp_pagerank(dg, r_prev, db)
    np.testing.assert_allclose(np.asarray(r_dfp), np.asarray(r_prev),
                               atol=1e-12)


def test_insertion_only_and_deletion_only_batches():
    g = random_graph(300, 2000, seed=12)
    dg = device_graph(g, d_p=8, tile=64)
    r_prev, _ = static_pagerank(dg, init_ranks(g.n))
    src, dst = g.edges()
    nonloop = src != dst
    from repro.core import BatchUpdate
    b_del = BatchUpdate(del_src=src[nonloop][:20], del_dst=dst[nonloop][:20],
                        ins_src=np.zeros(0, np.int32),
                        ins_dst=np.zeros(0, np.int32))
    b_ins = BatchUpdate(del_src=np.zeros(0, np.int32),
                        del_dst=np.zeros(0, np.int32),
                        ins_src=np.arange(20, dtype=np.int32),
                        ins_dst=np.arange(40, 60, dtype=np.int32))
    for b in (b_del, b_ins):
        g2 = apply_batch(g, b)
        dg2 = device_graph(g2, d_p=8, tile=64)
        db = batch_to_device(b, g.n)
        ref = reference_pagerank(g2)
        r, _ = dfp_pagerank(dg2, r_prev, db)
        assert l1_error(np.asarray(r), ref) < 1e-3
