"""Dry-run plumbing on a local (1,1) mesh with smoke configs: the same
jit + in_shardings + lower + compile + cost/memory analysis path the 512-dev
campaign uses, kept cheap enough for CI."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, smoke_config
from repro.models import LMModel
from repro.models.model import batch_specs, cache_specs, param_specs
from repro.roofline.analysis import analyze, collective_bytes


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("name", ["qwen2-1.5b", "deepseek-v3-671b",
                                  "recurrentgemma-2b"])
def test_lower_train_cell_smoke(name, mesh):
    cfg = smoke_config(get_config(name))
    model = LMModel(cfg, mesh=mesh)
    ap = model.abstract_params()
    ps = param_specs(cfg, ap, mesh)
    aopt = jax.eval_shape(model.init_opt, ap)
    os_ = model.opt_partition(ps)
    bshapes, bspecs = batch_specs(cfg, mesh, 4, 64)
    with mesh:
        fn = jax.jit(model.train_step,
                     in_shardings=(_ns(mesh, ps), _ns(mesh, os_),
                                   _ns(mesh, bspecs)),
                     donate_argnums=(0, 1))
        compiled = fn.lower(ap, aopt, bshapes).compile()
    ca = compiled.cost_analysis()
    assert ca.get("flops", 0) > 0
    rep = analyze("t", compiled, 1, 1.0)
    assert rep.hlo_flops > 0


@pytest.mark.parametrize("name", ["gemma2-9b", "rwkv6-1.6b"])
def test_lower_decode_cell_smoke(name, mesh):
    cfg = smoke_config(get_config(name))
    model = LMModel(cfg, mesh=mesh)
    ap = model.abstract_params()
    ps = param_specs(cfg, ap, mesh)
    bshapes, bspecs = batch_specs(cfg, mesh, 4, 1, decode=True)
    cshape, cspecs = cache_specs(cfg, mesh, 4, 32)
    with mesh:
        fn = jax.jit(model.decode_step,
                     in_shardings=(_ns(mesh, ps), _ns(mesh, cspecs),
                                   _ns(mesh, bspecs), None),
                     out_shardings=(None, _ns(mesh, cspecs)),
                     donate_argnums=(1,))
        compiled = fn.lower(ap, cshape, bshapes,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    assert compiled.memory_analysis() is not None


def test_int8_cache_and_t_sharding_lower(mesh):
    import dataclasses
    cfg = dataclasses.replace(smoke_config(get_config("gemma2-9b")),
                              kv_cache_dtype="int8", shard_cache_t=True)
    model = LMModel(cfg, mesh=mesh)
    ap = model.abstract_params()
    ps = param_specs(cfg, ap, mesh)
    bshapes, bspecs = batch_specs(cfg, mesh, 2, 1, decode=True)
    cshape, cspecs = cache_specs(cfg, mesh, 2, 32)
    leaves = jax.tree.leaves(cshape)
    assert any(l.dtype == jnp.int8 for l in leaves)
    with mesh:
        compiled = jax.jit(
            model.decode_step,
            in_shardings=(_ns(mesh, ps), _ns(mesh, cspecs),
                          _ns(mesh, bspecs), None)).lower(
            ap, cshape, bshapes, jax.ShapeDtypeStruct((), jnp.int32)
        ).compile()
    assert compiled is not None


def test_int8_decode_matches_bf16_closely():
    """Quantized cache decode must stay close to the fp cache decode."""
    import dataclasses
    from repro.models import transformer as tfm
    base = smoke_config(get_config("qwen2-1.5b"))
    q = dataclasses.replace(base, kv_cache_dtype="int8")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, base.vocab, (2, 10)), jnp.int32)
    outs = {}
    for cfg in (base, q):
        m = LMModel(cfg)
        params = m.init_params(jax.random.key(5))
        cache = tfm.init_cache(cfg, 2, 10)
        step = jax.jit(m.decode_step)
        for t in range(10):
            logits, cache = step(params, cache, {"tokens": toks[:, t:t + 1]},
                                 jnp.asarray(t, jnp.int32))
        outs[cfg.kv_cache_dtype] = np.asarray(logits)
    # int8 quantization noise is bounded; argmax should agree
    assert np.mean(np.argmax(outs["int8"], -1)
                   == np.argmax(outs["bfloat16"], -1)) > 0.9
