"""Unit tests for core.frontier: stream compaction, capacity plans, active
lists, push expansion, and the fstats counters (PR 8 tentpole machinery)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FrontierCaps, active_frontier, caps_for,
                        device_graph, expand_affected, expand_frontier,
                        forward_device_graph, init_ranks, merge_caps,
                        plan_capacity, powerlaw_graph, push_expand,
                        random_graph, stream_compact, update_ranks_active)
from repro.core.frontier import (FS_COMPACT, FS_ITERS, fstats_init,
                                 publish_fstats)
from repro.core.pagerank import update_ranks
from repro.obs.spans import Registry

CAPS = dict(d_p=8, tile=32)
STEP = dict(alpha=0.85, tau_f=1e-6, tau_p=1e-6, prune=True,
            closed_form=True, track_frontier=True)


# ---------------------------------------------------------------------------
# stream_compact
# ---------------------------------------------------------------------------

def test_stream_compact_matches_flatnonzero():
    rng = np.random.default_rng(0)
    flags = rng.random(517) < 0.13
    want = np.flatnonzero(flags)
    idx, cnt = stream_compact(jnp.asarray(flags), 128, fill=999)
    assert int(cnt) == want.size
    np.testing.assert_array_equal(np.asarray(idx)[:want.size], want)
    assert np.all(np.asarray(idx)[want.size:] == 999)


def test_stream_compact_truncates_and_reports_overflow():
    flags = jnp.ones(100, jnp.bool_)
    idx, cnt = stream_compact(flags, 16, fill=100)
    assert int(cnt) == 100          # count is exact even when k overflows
    np.testing.assert_array_equal(np.asarray(idx), np.arange(16))


def test_stream_compact_k_exceeds_input_length():
    flags = jnp.asarray([True, False, True])
    idx, cnt = stream_compact(flags, 8, fill=3)
    assert int(cnt) == 2
    np.testing.assert_array_equal(np.asarray(idx), [0, 2, 3, 3, 3, 3, 3, 3])


def test_stream_compact_empty_flags():
    idx, cnt = stream_compact(jnp.zeros(64, jnp.bool_), 8, fill=64)
    assert int(cnt) == 0
    assert np.all(np.asarray(idx) == 64)


# ---------------------------------------------------------------------------
# capacity plans
# ---------------------------------------------------------------------------

def test_plan_capacity_pow2_and_clamped():
    assert plan_capacity(10, 1 << 20) == 256          # 10*16 -> 160 -> 256
    assert plan_capacity(0, 1 << 20) == 16            # est floor of 1
    assert plan_capacity(10, 100) == 128              # clamp: next_pow2(n)
    assert plan_capacity(7, 1 << 20, headroom=2) == 16


def test_caps_for_clamps_to_layout_shapes():
    g = powerlaw_graph(500, 4000, seed=1)
    dg = device_graph(g, **CAPS)
    caps = caps_for(dg, est=3)
    for c, blk in zip(caps.bucket, dg.buckets):
        assert c <= int(blk.rows.shape[0])
    assert caps.hi <= dg.n_hi_cap
    assert caps.tiles <= int(dg.hi_tiles.shape[0])
    hash(caps)                      # must stay a valid jit static argument


def test_merge_caps_never_shrinks():
    a = FrontierCaps(bucket=(8, 4), hi=16, tiles=8, dn=32)
    b = FrontierCaps(bucket=(4, 16), hi=8, tiles=64, dn=16)
    m = merge_caps(a, b)
    assert m == FrontierCaps(bucket=(8, 16), hi=16, tiles=64, dn=32)
    assert merge_caps(None, b) == b


# ---------------------------------------------------------------------------
# active_frontier / update_ranks_active
# ---------------------------------------------------------------------------

def _setup(seed=2, n=400, m=3200):
    g = powerlaw_graph(n, m, seed=seed)
    dg = device_graph(g, **CAPS)
    rng = np.random.default_rng(seed + 1)
    dv = jnp.asarray(rng.random(n) < 0.05)
    return g, dg, dv


def test_active_frontier_lists_cover_exactly_the_affected_rows():
    _, dg, dv = _setup()
    caps = caps_for(dg, int(jnp.sum(dv)))
    af = active_frontier(dg.buckets, dg.hi_ids, dg.hi_rowmap, dv, caps)
    assert not bool(af.overflow)
    got = set()
    for blk, sel, cnt in zip(dg.buckets, af.bucket_sel, af.bucket_counts):
        slots = np.asarray(sel)[:int(cnt)]
        got |= set(np.asarray(blk.rows)[slots].tolist())
    hi = np.asarray(af.hi_sel)
    hi = hi[hi < dg.n_hi_cap]
    got |= set(np.asarray(dg.hi_ids)[hi].tolist())
    want = set(np.flatnonzero(np.asarray(dv)).tolist())
    assert got == want
    assert int(af.n_rows) == len(want)


def test_active_frontier_overflow_on_tiny_caps():
    _, dg, dv = _setup()
    caps = FrontierCaps(bucket=(1,) * len(dg.buckets), hi=1, tiles=1, dn=1)
    af = active_frontier(dg.buckets, dg.hi_ids, dg.hi_rowmap, dv, caps)
    assert bool(af.overflow)


def test_update_ranks_active_matches_dense_sweep():
    _, dg, dv = _setup(seed=5)
    r = init_ranks(dg.n)
    caps = caps_for(dg, int(jnp.sum(dv)))
    af = active_frontier(dg.buckets, dg.hi_ids, dg.hi_rowmap, dv, caps)
    assert not bool(af.overflow)
    dense = update_ranks(dg, r, dv, **STEP)
    act = update_ranks_active(dg, r, dv, af, **STEP)
    for a, b in zip(dense, act):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# push expansion
# ---------------------------------------------------------------------------

def test_push_expand_matches_dense_pull_expansion():
    g, dg, _ = _setup(seed=7)
    fwd = forward_device_graph(g, **CAPS)
    rng = np.random.default_rng(8)
    dn = jnp.asarray(rng.random(g.n) < 0.03)
    kn = plan_capacity(int(jnp.sum(dn)), g.n, headroom=4)
    marks, ovf = push_expand(fwd, dn, kn)
    assert not bool(ovf)
    want = expand_affected(dg, jnp.zeros(g.n, jnp.bool_), dn)
    np.testing.assert_array_equal(np.asarray(marks), np.asarray(want))


def test_push_expand_overflow_flag():
    g, _, _ = _setup(seed=9)
    fwd = forward_device_graph(g, **CAPS)
    dn = jnp.ones(g.n, jnp.bool_)
    _, ovf = push_expand(fwd, dn, kn=4)
    assert bool(ovf)


def test_expand_frontier_equals_dense_both_sides_of_overflow():
    g, dg, _ = _setup(seed=11)
    fwd = forward_device_graph(g, **CAPS)
    rng = np.random.default_rng(12)
    dv = jnp.asarray(rng.random(g.n) < 0.02)
    dn = jnp.asarray(rng.random(g.n) < 0.04)
    want = expand_affected(dg, dv, dn)
    for caps in (caps_for(dg, g.n),                      # compacted path
                 FrontierCaps(bucket=(1,) * len(dg.buckets), hi=1,
                              tiles=1, dn=1)):           # overflow fallback
        got, stats = expand_frontier(dg, fwd, dv, dn, caps)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert stats.shape == (3,)


# ---------------------------------------------------------------------------
# fstats
# ---------------------------------------------------------------------------

def test_publish_fstats_lands_in_registry():
    fs = fstats_init(2)
    fs = fs.at[FS_ITERS].add(5).at[FS_COMPACT].add(4)
    reg = Registry()
    publish_fstats(fs, registry=reg)
    assert reg.counter("frontier.iters") == 5
    assert reg.counter("frontier.compact_iters") == 4
