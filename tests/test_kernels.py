"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device_graph, init_ranks, powerlaw_graph, pull_sum, static_pagerank
from repro.kernels import ref as kref
from repro.kernels.csr_block import csr_block_pull
from repro.kernels.ell_pull import ell_pull
from repro.kernels.linf_delta import linf_delta
from repro.kernels.ops import pull_sum_kernels, update_ranks_kernel
from repro.kernels.pr_update import pr_update


@pytest.mark.parametrize("n,d_p,vt", [(100, 4, 32), (257, 8, 64),
                                      (1000, 16, 512), (64, 4, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_ell_pull_sweep(n, d_p, vt, dtype, rng):
    idx = jnp.asarray(rng.integers(0, n, size=(n, d_p)), jnp.int32)
    mask = jnp.asarray(rng.random((n, d_p)) < 0.7, jnp.float32)
    c = jnp.asarray(rng.random(n), dtype)
    out = ell_pull(c, idx, mask, vt=vt)
    ref = kref.ell_pull_ref(c, idx, mask)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)
    assert out.dtype == c.dtype


@pytest.mark.parametrize("t_cap,tile,n_rows", [(8, 16, 3), (33, 8, 7),
                                               (64, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_csr_block_pull_sweep(t_cap, tile, n_rows, dtype, rng):
    n = 500
    tiles = jnp.asarray(rng.integers(0, n, size=(t_cap, tile)), jnp.int32)
    tmask = jnp.asarray(rng.random((t_cap, tile)) < 0.5, jnp.float32)
    rowmap = jnp.asarray(rng.integers(0, n_rows, size=t_cap), jnp.int32)
    c = jnp.asarray(rng.random(n), dtype)
    out = csr_block_pull(c, tiles, tmask, rowmap, n_rows)
    ref = kref.csr_block_pull_ref(c, tiles, tmask, rowmap, n_rows)
    tol = 1e-4 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


@pytest.mark.parametrize("n,vt", [(100, 64), (1025, 256)])
@pytest.mark.parametrize("prune,closed_form", [(True, True), (False, False),
                                               (True, False)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_pr_update_sweep(n, vt, prune, closed_form, dtype, rng):
    contrib = jnp.asarray(rng.random(n), dtype) * 0.01
    r = jnp.asarray(rng.random(n), dtype) * 0.01 + 1e-4
    deg = jnp.asarray(rng.integers(1, 40, size=n), jnp.int32)
    aff = jnp.asarray(rng.random(n) < 0.6, dtype)
    kw = dict(alpha=0.85, inv_n=1.0 / n, tau_f=1e-4, tau_p=1e-4,
              prune=prune, closed_form=closed_form)
    rk, ak, dk, mk = pr_update(contrib, r, deg, aff, vt=vt, **kw)
    rr, ar, dr_, mr = kref.pr_update_ref(contrib, r, deg, aff, **kw)
    tol = 1e-6 if dtype == jnp.float32 else 1e-14
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), atol=tol)
    np.testing.assert_array_equal(np.asarray(ak), np.asarray(ar))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr_))
    np.testing.assert_allclose(float(mk), float(mr), atol=tol)


@pytest.mark.parametrize("n,vt", [(10, 8), (1000, 128), (4096, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_linf_delta_sweep(n, vt, dtype, rng):
    a = jnp.asarray(rng.standard_normal(n), dtype)
    b = jnp.asarray(rng.standard_normal(n), dtype)
    out = linf_delta(a, b, vt=vt)
    np.testing.assert_allclose(float(out), float(kref.linf_delta_ref(a, b)),
                               rtol=1e-6)


def test_kernel_pull_matches_core_pull():
    g = powerlaw_graph(500, 4000, seed=5)
    dg = device_graph(g, d_p=8, tile=64)
    c = init_ranks(g.n) / dg.out_deg.astype(jnp.float64)
    a = pull_sum(dg, c)
    b = pull_sum_kernels(dg, c, vt=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-14)


def test_static_pagerank_with_kernel_backend_identical():
    g = powerlaw_graph(300, 2500, seed=6)
    dg = device_graph(g, d_p=8, tile=64)
    r_j, it_j = static_pagerank(dg, init_ranks(g.n))
    r_k, it_k = static_pagerank(
        dg, init_ranks(g.n),
        pull_sum_fn=lambda d, c: pull_sum_kernels(d, c, vt=128))
    assert int(it_j) == int(it_k)
    np.testing.assert_allclose(np.asarray(r_j), np.asarray(r_k), atol=1e-15)


def test_update_ranks_kernel_contract():
    g = powerlaw_graph(200, 1500, seed=7)
    dg = device_graph(g, d_p=8, tile=64)
    r = init_ranks(g.n)
    aff = jnp.ones(g.n, jnp.bool_)
    from repro.core.pagerank import update_ranks
    out_core = update_ranks(dg, r, aff, alpha=0.85, tau_f=1e-6, tau_p=1e-6,
                            prune=True, closed_form=True, track_frontier=True)
    out_kern = update_ranks_kernel(dg, r, aff, alpha=0.85, tau_f=1e-6,
                                   tau_p=1e-6, prune=True, closed_form=True,
                                   track_frontier=True)
    np.testing.assert_allclose(np.asarray(out_core[0]),
                               np.asarray(out_kern[0]), atol=1e-14)
    np.testing.assert_array_equal(np.asarray(out_core[1]),
                                  np.asarray(out_kern[1]))
    np.testing.assert_array_equal(np.asarray(out_core[2]),
                                  np.asarray(out_kern[2]))


@pytest.mark.parametrize("S,T,D,bq,bk", [(64, 64, 16, 16, 16),
                                         (128, 128, 32, 64, 32),
                                         (32, 32, 8, 32, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(S, T, D, bq, bk, causal, rng):
    from repro.kernels.flash_attn import flash_attention
    q = jnp.asarray(rng.standard_normal((4, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((4, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4, T, D)), jnp.float32)
    out = flash_attention(q, k, v, bq=bq, bk=bk, causal=causal)
    ref = kref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_chunked_schedule(rng):
    """The Pallas kernel and the model's jnp chunked attention agree."""
    from repro.kernels.flash_attn import flash_attention
    from repro.models.attention import chunked_attention
    B, S, H, D = 2, 64, 4, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    jnp_out = chunked_attention(q, k, v, chunk=16)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    pl_out = flash_attention(qf, kf, vf, bq=16, bk=16).reshape(
        B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(jnp_out), np.asarray(pl_out),
                               atol=3e-3, rtol=3e-3)
