"""Roofline machinery tests: param counting, analytic costs, specs."""
import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, shape_applies
from repro.models import LMModel
from repro.models.model import param_specs
from repro.roofline.analysis import count_params, model_flops
from repro.roofline.analytic import cost_for

MESH_1POD = {"data": 16, "model": 16}


def _actual_params(name):
    cfg = get_config(name)
    ap = LMModel(cfg).abstract_params()
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(ap))


@pytest.mark.parametrize("name,nominal_b", [
    ("deepseek-v3-671b", 671), ("dbrx-132b", 132), ("gemma2-9b", 9.2),
    ("qwen2-1.5b", 1.5), ("qwen3-4b", 4.0), ("smollm-360m", 0.36),
    ("rwkv6-1.6b", 1.6), ("recurrentgemma-2b", 2.7),
    ("musicgen-large", 3.3), ("qwen2-vl-2b", 1.5),
])
def test_param_counts_near_nominal(name, nominal_b):
    """Instantiated parameter count is within 40% of the published size
    (configs come from the assignment; embeddings/frontends cause slack)."""
    actual = _actual_params(name)
    assert 0.6 * nominal_b * 1e9 < actual < 1.55 * nominal_b * 1e9, \
        (name, actual / 1e9)


@pytest.mark.parametrize("name", list_configs())
def test_analytic_count_matches_instantiated(name):
    """roofline.count_params (analytic) vs real init, within 15%."""
    total, active = count_params(get_config(name))
    actual = _actual_params(name)
    assert abs(total - actual) / actual < 0.15, (name, total / 1e9,
                                                 actual / 1e9)
    assert active <= total + 1


@pytest.mark.parametrize("name", list_configs())
@pytest.mark.parametrize("shape", list(SHAPES))
def test_analytic_cost_positive(name, shape):
    cfg = get_config(name)
    sh = SHAPES[shape]
    ok, _ = shape_applies(cfg, sh)
    if not ok:
        pytest.skip("shape not applicable")
    c = cost_for(cfg, sh, MESH_1POD)
    assert c.flops > 0 and c.hbm_bytes > 0 and c.mem_bytes > 0
    # decode flops must be tiny vs train flops
    if sh.kind == "decode":
        tr = cost_for(cfg, SHAPES["train_4k"], MESH_1POD)
        assert c.flops < tr.flops / 100


def test_model_flops_scale():
    cfg = get_config("qwen2-1.5b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    # 6·N·D ballpark: 6 × 1.5e9 × 1e6 ≈ 9.5e15
    assert 3e15 < f_train < 3e16


def test_param_specs_divisible_everywhere():
    """Every sharded dim must divide by its mesh axes (post-sanitize)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for name in list_configs():
        cfg = get_config(name)
        ap = LMModel(cfg).abstract_params()
        specs = param_specs(cfg, ap, FakeMesh())
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(ap)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: hasattr(x, "_normalized_spec")
                    or str(type(x).__name__) == "PartitionSpec")[0]):
            for i, s in enumerate(spec):
                if s is None:
                    continue
                axes = s if isinstance(s, tuple) else (s,)
                size = 1
                for a in axes:
                    size *= FakeMesh.shape[a]
                assert leaf.shape[i] % size == 0, (name, path, spec,
                                                   leaf.shape)
