"""Per-architecture smoke tests (reduced configs): one train step + one
decode step on CPU, asserting shapes and no NaNs — plus step-decode vs
full-forward parity (validates KV caches, MLA latent cache, rwkv/rglru
recurrent states against the chunked/parallel training path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, smoke_config
from repro.models import LMModel
from repro.models import transformer as tfm

ARCHS = list_configs()


def _batch(cfg, B, S, rng):
    if cfg.embed_inputs:
        b = {"embeddings": jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                                       jnp.float32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    else:
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.rope == "mrope":
        b["positions"] = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                          (B, 3, S))
    return b


@pytest.mark.parametrize("name", ARCHS)
def test_arch_train_step(name, rng):
    cfg = smoke_config(get_config(name))
    m = LMModel(cfg)
    params = m.init_params(jax.random.key(0))
    B, S = 2, 64
    batch = _batch(cfg, B, S, rng)
    loss, metrics = m.loss(params, batch)
    assert np.isfinite(float(loss)), name
    opt = m.init_opt(params)
    p2, o2, mets = jax.jit(m.train_step)(params, opt, batch)
    assert np.isfinite(float(mets["loss"]))
    assert np.isfinite(float(mets["grad_norm"]))
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("name", ARCHS)
def test_arch_decode_step(name, rng):
    cfg = smoke_config(get_config(name))
    m = LMModel(cfg)
    params = m.init_params(jax.random.key(1))
    B, T = 2, 32
    cache = tfm.init_cache(cfg, B, T)
    batch = _batch(cfg, B, 1, rng)
    batch.pop("labels", None)
    step = jax.jit(m.decode_step)
    logits, cache = step(params, cache, batch, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    logits, cache = step(params, cache, batch, jnp.asarray(1, jnp.int32))
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_full_forward(name, rng):
    """Feed S tokens one-by-one through decode; the final-step logits must
    match the full (chunked/parallel) forward pass at the last position."""
    cfg = smoke_config(get_config(name))
    m = LMModel(cfg)
    params = m.init_params(jax.random.key(2))
    B, S = 2, 16
    batch = _batch(cfg, B, S, rng)
    full_logits, _, _ = tfm.forward_full(params, cfg, batch)

    cache = tfm.init_cache(cfg, B, S)
    step = jax.jit(m.decode_step)
    for t in range(S):
        if cfg.embed_inputs:
            db = {"embeddings": batch["embeddings"][:, t:t + 1]}
        else:
            db = {"tokens": batch["tokens"][:, t:t + 1]}
        logits, cache = step(params, cache, db, jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-2, rtol=2e-2)


def test_moe_router_balance_loss_positive():
    cfg = smoke_config(get_config("dbrx-132b"))
    m = LMModel(cfg)
    params = m.init_params(jax.random.key(3))
    batch = _batch(cfg, 2, 64, np.random.default_rng(0))
    _, metrics = m.loss(params, batch)
    assert float(metrics["aux"]) > 0.0


def test_full_configs_instantiate_abstract():
    """FULL configs must build abstract params without allocation."""
    for name in ARCHS:
        cfg = get_config(name)
        m = LMModel(cfg)
        ap = m.abstract_params()
        n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(ap))
        assert n_params > 1e8, (name, n_params)  # all assigned archs >100M
