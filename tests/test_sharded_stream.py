"""Sharded streaming: ShardedSnapshot maintenance + mesh-mode StreamSession.

Acceptance bar (ISSUE 2): on a >= 2-shard host mesh, every batch of a
replayed stream ends within L1 1e-8 of a from-scratch static solve, with
per-batch maintenance restaging only touched rows — no O(|E|) re-partition.
Subprocess: XLA fixes the device count at first init.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core import temporal_stream, powerlaw_graph, l1_error
    from repro.core.distributed import sharded_caps
    from repro.stream import ShardedSnapshot, StreamSession, ingest, replay
    from repro.stream.replay import churn_workload

    assert len(jax.devices()) == 4, jax.devices()
    mesh = jax.make_mesh((2, 2), ("data", "model"))

    # --- insertion-only temporal stream (paper 5.1.4 protocol) ------------
    base, batches = temporal_stream(2500, 35000, n_batches=6, seed=3)
    sess = StreamSession(base, mesh=mesh, d_p=16, tile=64)
    caps0 = sharded_caps(sess.snap.sg)
    recs = replay(sess, batches, verify_every=1)
    for rec in recs:
        assert rec.l1_vs_static is not None and rec.l1_vs_static < 1e-8, (
            rec.t, rec.l1_vs_static)
        st = rec.stats
        assert st.engine == "sharded", st.engine
        # incremental maintenance, not O(|E|) re-partition: nothing rebuilt,
        # and the refresh touched only O(|batch|) rows of the stacked layout
        assert not st.snapshot.rebuilt, st.snapshot.rebuild_reason
        assert 0 < st.snapshot.rows_touched <= 4 * st.batch_size
    # capacity discipline: device shapes never changed across the stream
    assert sharded_caps(sess.snap.sg) == caps0

    # --- churn (deletions + degree crossings) on a power-law base ---------
    g = powerlaw_graph(1500, 25000, seed=4)
    sess2 = StreamSession(g, mesh=mesh, d_p=16, tile=64)
    for b in churn_workload(g, 0.003, 4, seed=9):
        sess2.apply(b)
        err = l1_error(np.asarray(sess2.flat_ranks()),
                       np.asarray(sess2.static_reference()))
        assert err < 1e-8, err
        assert not sess2.history[-1].snapshot.rebuilt

    # --- snapshot-level parity: maintained sg == freshly built sg ---------
    snap = sess2.snap
    from repro.core.distributed import build_sharded
    fresh = build_sharded(snap.graph(), snap.nd, d_p=16, tile=64,
                          **{k: v for k, v in sharded_caps(snap.sg).items()
                             if k in ("hi_cap", "t_cap")})
    # same edge multiset per shard row: compare row-sums of a random vector
    x = np.random.default_rng(0).random(snap.n_pad)
    from repro.core.distributed import _local_pull, _as_dict
    def pull_all(sg):
        d = _as_dict(sg)
        return np.stack([np.asarray(_local_pull(
            jax.tree.map(lambda v: v[s], d), jnp.asarray(x)))
            for s in range(snap.nd)])
    np.testing.assert_allclose(pull_all(snap.sg), pull_all(fresh),
                               rtol=1e-12)

    # --- sharded session tracks the single-device session -----------------
    sess_sd = StreamSession(base, d_p=16, tile=64)
    sess_md = StreamSession(base, mesh=mesh, d_p=16, tile=64)
    for b in batches[:3]:
        sess_sd.apply(b)
        sess_md.apply(b)
    err = l1_error(np.asarray(sess_md.flat_ranks()),
                   np.asarray(sess_sd.flat_ranks()))
    assert err < 1e-8, err
    ids_sd, _ = sess_sd.topk(5)
    ids_md, _ = sess_md.topk(5)
    assert list(ids_sd) == list(ids_md), (ids_sd, ids_md)
    print("OK")
""")


@pytest.mark.slow
def test_sharded_stream_4dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
