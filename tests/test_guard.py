"""repro.guard — fault-tolerant streaming sessions (ISSUE 9).

Chaos recovery suite: every fault class the guard layer claims to survive
is injected deterministically (``ChaosMonkey``) and must be (a) detected —
the right ``guard.*`` counter/health bit fires — and (b) recovered — the
escalation ladder or ``StreamSession.restore`` lands the session within
L1 1e-8 of a trustworthy static solve, bit-identical for crash replay.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.graph import (BatchUpdate, apply_batch, random_batch,
                              random_graph, temporal_stream)
from repro.core.dynamic import dfp_pagerank
from repro.core.compact import dfp_pagerank_compact
from repro.core.pagerank import (PRParams, device_graph, init_ranks,
                                 static_pagerank)
from repro.core.reference import l1_error
from repro.guard import (ChaosMonkey, DeltaJournal, GuardConfig,
                         H_MASS_DRIFT, H_MAX_ITER, H_NONFINITE, HEALTH_OK,
                         JournalRecord, QuarantineReport, ValidationError,
                         describe_health, health_flags, health_word,
                         journal_path, validate_batch)
from repro.obs.spans import get_registry, reset_registry
from repro.stream import DeviceSnapshot, StreamSession, ingest
from repro.stream.delta import Delta

pytestmark = pytest.mark.guard

N, M = 512, 4096


@pytest.fixture()
def g():
    return random_graph(N, M, seed=0)


@pytest.fixture(scope="module")
def tstream():
    """Acceptance-scale temporal stream (paper §5.1.4 protocol, same sizes
    as tests/test_sharded_stream.py): chained DF-P drift on graphs this
    size stays under the L1 1e-8 acceptance bound — the tiny ``g`` fixture
    drifts a few e-8 legitimately and is only used where the comparison
    anchor is exact (recompute / audit resync / bit-identity)."""
    return temporal_stream(2500, 35000, n_batches=8, seed=3)


@pytest.fixture(autouse=True)
def _fresh_registry():
    reset_registry()
    yield
    reset_registry()


def _empty_batch():
    z = np.zeros(0, np.int64)
    return BatchUpdate(del_src=z, del_dst=z, ins_src=z, ins_dst=z)


# ---------------------------------------------------------------------------
# piece 1: ingest validation & quarantine
# ---------------------------------------------------------------------------

def test_validate_strict_raises_out_of_range(g):
    chaos = ChaosMonkey(seed=1)
    bad = chaos.corrupt_batch(_empty_batch(), N, mode="out_of_range", k=4)
    with pytest.raises(ValidationError):
        validate_batch(bad, N)


def test_validate_quarantine_strips_and_counts(g):
    chaos = ChaosMonkey(seed=1)
    good = random_batch(g, 16, seed=3)
    bad = chaos.corrupt_batch(good, N, mode="out_of_range", k=4)
    clean, report = validate_batch(bad, N, policy="quarantine")
    assert isinstance(report, QuarantineReport) and report.size == 4
    assert bool(report)
    # the clean remainder is exactly the original batch's pairs
    assert clean.ins_src.shape[0] == bad.ins_src.shape[0] - 4
    assert get_registry().counter("guard.quarantined") == 4
    assert get_registry().counter("guard.quarantined_batches") == 1


@pytest.mark.parametrize("mangle", [
    lambda b: BatchUpdate(b.del_src, b.del_dst, b.ins_src[:-1], b.ins_dst),
    lambda b: BatchUpdate(b.del_src, b.del_dst,
                          b.ins_src.astype(np.float64), b.ins_dst),
    lambda b: BatchUpdate(b.del_src, b.del_dst,
                          b.ins_src.reshape(1, -1), b.ins_dst.reshape(1, -1)),
])
def test_validate_structural_always_fatal(g, mangle):
    b = mangle(random_batch(g, 8, seed=4))
    for policy in ("raise", "quarantine"):
        with pytest.raises(ValidationError):
            validate_batch(b, N, policy=policy)


def test_ingest_strict_default_rejects_aliasing_ids(g):
    """Satellite (a): ids outside [0, n) alias other edges under the
    src*n + dst key packing — strict ingest must refuse them."""
    chaos = ChaosMonkey(seed=2)
    bad = chaos.corrupt_batch(random_batch(g, 8, seed=5), N,
                              mode="out_of_range")
    with pytest.raises(ValidationError):
        ingest(bad, N)
    # quarantine policy ingests the clean remainder
    delta = ingest(bad, N, policy="quarantine")
    assert delta.size > 0
    assert (delta.ins_dst >= 0).all() and (delta.ins_dst < N).all()


def test_ingest_dup_flood_coalesces(g):
    chaos = ChaosMonkey(seed=3)
    flooded = chaos.corrupt_batch(_empty_batch(), N, mode="dup_flood", k=64)
    delta = ingest(flooded, N)
    assert delta.ni == 1  # 64 copies of one pair -> one edge


# ---------------------------------------------------------------------------
# piece 2: health word — unit + engine loops (satellite d)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delta,iters,mass,expect", [
    (1e-12, 10, 1.0, HEALTH_OK),
    (1e-3, 500, 1.0, H_MAX_ITER),          # budget out, still above tau
    (1e-12, 500, 1.0, HEALTH_OK),          # converged ON the last sweep
    (np.nan, 1, np.nan, H_NONFINITE),
    (1e-12, 10, 1.5, H_MASS_DRIFT),
    (np.nan, 500, 1.5, H_NONFINITE | H_MASS_DRIFT),
])
def test_health_word_bits(delta, iters, mass, expect):
    w = int(health_word(jnp.asarray(delta), jnp.asarray(iters),
                        jnp.asarray(mass), tau=1e-10, max_iter=500))
    assert w == expect, (describe_health(w), describe_health(expect))


def test_health_flags_decode():
    assert health_flags(HEALTH_OK) == ()
    assert describe_health(HEALTH_OK) == "ok"
    assert health_flags(H_MAX_ITER | H_MASS_DRIFT) == ("max_iter",
                                                       "mass_drift")


def _solve_with_health(engine, g, params):
    """Run one engine loop with health=True; returns (r, iters, hw)."""
    dg = device_graph(g, d_p=16, tile=64)
    if engine == "static":
        return static_pagerank(dg, init_ranks(g.n), params, health=True)
    b = random_batch(g, 32, seed=9)
    delta = ingest(b, g.n)
    g2 = apply_batch(g, b)
    r0, _ = static_pagerank(dg, init_ranks(g.n), PRParams())
    snap = DeviceSnapshot(g2, d_p=16, tile=64)
    db = delta.to_device()
    if engine == "dense":
        return dfp_pagerank(snap, r0, db, params, health=True)
    return dfp_pagerank_compact(snap, None, r0, db, params, health=True)


@pytest.mark.parametrize("engine", ["static", "dense", "compact"])
def test_health_trips_exactly_at_budget_exhaustion(g, engine):
    """Satellite (d): across engine loops the H_MAX_ITER bit is set exactly
    when iters == max_iter AND the final L∞ delta is still above tau."""
    # full budget: converges, word clean
    r, iters, hw = _solve_with_health(engine, g, PRParams())
    assert int(hw) == HEALTH_OK, describe_health(int(hw))
    assert int(iters) < PRParams().max_iter
    # starved budget: exits at max_iter with delta > tau -> flag set
    r, iters, hw = _solve_with_health(engine, g,
                                      PRParams(max_iter=1))
    assert int(iters) == 1
    assert int(hw) & H_MAX_ITER, describe_health(int(hw))


@pytest.mark.parametrize("engine", ["static", "dense", "compact"])
def test_health_converged_on_final_sweep_is_clean(g, engine):
    """iters == max_iter alone must NOT trip: pin max_iter to the exact
    iteration count of the converged solve and re-run."""
    r, iters, hw = _solve_with_health(engine, g, PRParams())
    assert int(hw) == HEALTH_OK
    r2, iters2, hw2 = _solve_with_health(
        engine, g, PRParams(max_iter=int(iters)))
    assert int(iters2) == int(iters)
    assert int(hw2) == HEALTH_OK, describe_health(int(hw2))


def test_nan_poison_detected_in_one_sweep(g):
    """NaN > tau is False: a poisoned solve exits after ONE sweep with the
    nonfinite bit set instead of spinning to max_iter."""
    chaos = ChaosMonkey(seed=5)
    dg = device_graph(g, d_p=16, tile=64)
    r0, _ = static_pagerank(dg, init_ranks(g.n), PRParams())
    b = random_batch(g, 16, seed=11)
    delta = ingest(b, g.n)
    snap = DeviceSnapshot(apply_batch(g, b), d_p=16, tile=64)
    r_bad = chaos.poison_ranks(r0, mode="nan", k=2)
    r, iters, hw = dfp_pagerank(snap, r_bad, delta.to_device(), PRParams(),
                                health=True)
    assert int(hw) & H_NONFINITE
    assert int(iters) <= 2, int(iters)


# ---------------------------------------------------------------------------
# session integration: noop, recompute, ladder, audit
# ---------------------------------------------------------------------------

def test_empty_batch_is_noop(g):
    """Satellite (b): an empty delta skips snapshot, solve and journal."""
    sess = StreamSession(g, guard=GuardConfig())
    r_before = sess.ranks
    r = sess.apply(_empty_batch())
    st = sess.history[-1]
    assert st.engine == "noop" and st.batch_size == 0 and st.iters == 0
    assert st.snapshot.rows_touched == 0 and st.solve_s == 0.0
    assert r is r_before  # not even a copy
    assert get_registry().counter("session.engine.noop") == 1
    assert sess._batch_idx == 0  # noops hold no sequence number


def test_fully_quarantined_batch_is_noop(g):
    sess = StreamSession(g, guard=GuardConfig(policy="quarantine"))
    chaos = ChaosMonkey(seed=6)
    bad = chaos.corrupt_batch(_empty_batch(), N, mode="out_of_range", k=4)
    sess.apply(bad)
    st = sess.history[-1]
    assert st.engine == "noop" and st.quarantined == 4


def test_recompute_records_history_and_counter(g):
    """Satellite (c): recompute() is visible in the accounting stream."""
    sess = StreamSession(g)
    h0 = len(sess.history)
    r = sess.recompute()
    assert len(sess.history) == h0 + 1
    st = sess.history[-1]
    assert st.engine == "recompute" and st.iters > 0 and st.solve_s > 0
    assert get_registry().counter("session.recompute") == 1
    assert l1_error(np.asarray(sess.flat_ranks()),
                    np.asarray(sess.static_reference())) < 1e-12


def test_ladder_recovers_forced_nonconvergence(tstream):
    base, batches = tstream
    sess = StreamSession(base, d_p=16, tile=64, guard=GuardConfig())
    chaos = ChaosMonkey(seed=7)
    chaos.force_nonconvergence(sess)          # max_iter=1 per batch
    sess.apply(batches[0])
    st = sess.history[-1]
    assert st.health & H_MAX_ITER
    assert st.escalations >= 1
    obs = get_registry()
    assert obs.counter("guard.unhealthy") == 1
    assert obs.counter("guard.health.max_iter") == 1
    assert obs.counter("guard.escalate.dense") == 1
    assert obs.counter("guard.escalate.success") == 1
    # recovery used the full-budget recovery params: within 1e-8 of a
    # full-budget static solve on the updated snapshot
    ref, _ = static_pagerank(sess.snap.dg, init_ranks(sess.n),
                             sess.params._replace(max_iter=500))
    assert l1_error(np.asarray(sess.flat_ranks()), np.asarray(ref)) < 1e-8


def test_ladder_recovers_nan_poison(g):
    sess = StreamSession(g, guard=GuardConfig())
    chaos = ChaosMonkey(seed=8)
    sess.ranks = chaos.poison_ranks(sess.ranks, mode="nan", k=1, idx=[3])
    sess.apply(random_batch(g, 16, seed=13))
    st = sess.history[-1]
    assert st.health & H_NONFINITE
    assert st.escalations >= 1
    assert get_registry().counter("guard.escalate.success") == 1
    assert l1_error(np.asarray(sess.flat_ranks()),
                    np.asarray(sess.static_reference())) < 1e-8


def test_ladder_exhaustion_counted(g):
    """retry_budget=0 walks no rungs and reports exhaustion."""
    sess = StreamSession(g, guard=GuardConfig(retry_budget=0))
    ChaosMonkey(seed=9).force_nonconvergence(sess)
    sess.apply(random_batch(g, 32, seed=14))
    obs = get_registry()
    assert obs.counter("guard.unhealthy") == 1
    assert obs.counter("guard.escalate.exhausted") == 1
    assert obs.counter("guard.escalate.success") == 0


def test_audit_resyncs_frozen_lane_corruption(g):
    """A finite bit-flip OUTSIDE the batch frontier survives the solve (the
    lane is never re-swept — DF-P freezes unaffected vertices by design);
    the periodic drift audit must catch and resync it."""
    chaos = ChaosMonkey(seed=10)
    # huge mass_tol: the per-solve watchdog is blind here on purpose, so
    # detection must come from the audit
    sess = StreamSession(g, guard=GuardConfig(audit_every=1, audit_tol=1e-8,
                                              mass_tol=1e30))
    sess.ranks = chaos.poison_ranks(sess.ranks, mode="bitflip", k=1, idx=[2])
    sess.apply(random_batch(g, 8, seed=15))
    obs = get_registry()
    assert obs.counter("guard.audit.runs") == 1
    assert obs.counter("guard.audit.resync") == 1
    assert l1_error(np.asarray(sess.flat_ranks()),
                    np.asarray(sess.static_reference())) < 1e-8


def test_mass_tol_override_reaches_watchdog(g):
    """GuardConfig.mass_tol re-judges the engines' baked-in default."""
    sess = StreamSession(g, guard=GuardConfig(mass_tol=1e-12))
    sess.apply(random_batch(g, 16, seed=16))
    # healthy chained DF-P drifts Σ R by O(tau_f) > 1e-12: with a
    # pathologically tight tolerance the drift bit must fire
    st = sess.history[-1]
    assert st.health & H_MASS_DRIFT
    assert get_registry().counter("guard.health.mass_drift") >= 1


# ---------------------------------------------------------------------------
# piece 3: journal + checkpoint / restore
# ---------------------------------------------------------------------------

def _zigzag(n, k, seed):
    rng = np.random.default_rng(seed)
    return JournalRecord(
        seq=k, n=n,
        del_src=rng.integers(0, n, 3).astype(np.int32),
        del_dst=rng.integers(0, n, 3).astype(np.int32),
        ins_src=rng.integers(0, n, 5).astype(np.int32),
        ins_dst=rng.integers(0, n, 5).astype(np.int32))


def test_journal_roundtrip(tmp_path):
    path = journal_path(str(tmp_path))
    j = DeltaJournal(path)
    recs = [_zigzag(N, k, k) for k in range(1, 6)]
    for r in recs:
        j.append(r)
    j.close()
    out, truncated = DeltaJournal.scan(path)
    assert not truncated and len(out) == 5
    for a, b in zip(recs, out):
        assert a.seq == b.seq and a.n == b.n
        for f in ("del_src", "del_dst", "ins_src", "ins_dst"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))


def test_journal_torn_tail_longest_prefix(tmp_path):
    path = journal_path(str(tmp_path))
    j = DeltaJournal(path)
    for k in range(1, 6):
        j.append(_zigzag(N, k, k))
    j.close()
    size = os.path.getsize(path)
    ChaosMonkey(seed=11).truncate_journal(path, nbytes=size - 7)
    out, truncated = DeltaJournal.scan(path)
    assert truncated
    assert len(out) == 4  # exactly the records before the tear
    assert [r.seq for r in out] == [1, 2, 3, 4]
    assert get_registry().counter("guard.journal.truncated") == 1


def test_restore_bit_identical(tmp_path, g):
    """Acceptance: kill-and-restore replay is BIT-identical — ranks and the
    full snapshot state (free-list order included)."""
    d = str(tmp_path)
    sess = StreamSession(g, guard=GuardConfig(), journal_dir=d,
                         checkpoint_every=2)
    for i in range(5):
        sess.apply(random_batch(sess.snap.graph(), 32, seed=20 + i))
    sess.close()

    restored = StreamSession.restore(d)
    assert restored._batch_idx == sess._batch_idx == 5
    assert np.array_equal(np.asarray(sess.ranks), np.asarray(restored.ranks))
    A, ea = sess.snap.state_dict()
    B, eb = restored.snap.state_dict()
    assert set(A) == set(B)
    for k in A:
        assert np.array_equal(np.asarray(A[k]), np.asarray(B[k])), k
    assert ea == eb
    assert get_registry().counter("guard.restores") == 1
    # and the restored session keeps streaming identically
    b = random_batch(sess.snap.graph(), 16, seed=99)
    r1, r2 = sess.apply(b), restored.apply(b)
    assert np.array_equal(np.asarray(r1), np.asarray(r2))


def test_restore_survives_torn_journal(tmp_path, tstream):
    base, batches = tstream
    d = str(tmp_path)
    sess = StreamSession(base, d_p=16, tile=64, journal_dir=d,
                         checkpoint_every=3)
    for b in batches[:5]:
        sess.apply(b)
    sess.close()
    # tear the tail: the torn record is dropped, everything to the last
    # intact record replays on top of the step-3 checkpoint
    size = os.path.getsize(journal_path(d))
    ChaosMonkey(seed=12).truncate_journal(journal_path(d), nbytes=size - 3)
    restored = StreamSession.restore(d)
    assert 4 <= restored._batch_idx <= 5
    assert restored._batch_idx == 4
    ref = restored.static_reference()
    assert l1_error(np.asarray(restored.flat_ranks()),
                    np.asarray(ref)) < 1e-8


def test_restore_config_fidelity(tmp_path, g):
    d = str(tmp_path)
    guard = GuardConfig(policy="quarantine", retry_budget=3, audit_every=7)
    sess = StreamSession(g, params=PRParams(tau_f=1e-9, tau_p=1e-9,
                                            max_iter=321),
                         guard=guard, journal_dir=d, checkpoint_every=1,
                         engine="dense", d_p=32, tile=128)
    sess.apply(random_batch(g, 8, seed=50))
    sess.close()
    restored = StreamSession.restore(d)
    assert restored.params == sess.params
    assert restored.guard == guard
    assert restored.engine == "dense"
    assert restored._d_p == 32 and restored._tile == 128


def test_journal_write_ahead_ordering(tmp_path, g):
    """The journal record lands before the solve: a session killed right
    after apply() still has every applied batch on disk."""
    d = str(tmp_path)
    sess = StreamSession(g, journal_dir=d, checkpoint_every=0)
    for i in range(3):
        sess.apply(random_batch(sess.snap.graph(), 8, seed=60 + i))
    sess.close()
    recs, truncated = DeltaJournal.scan(journal_path(d))
    assert not truncated and [r.seq for r in recs] == [1, 2, 3]


# ---------------------------------------------------------------------------
# sharded session health (subprocess: XLA pins device count at first init)
# ---------------------------------------------------------------------------

_SHARDED = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.core import l1_error, random_batch, random_graph
    from repro.guard import ChaosMonkey, GuardConfig, H_NONFINITE
    from repro.stream import StreamSession

    assert len(jax.devices()) == 4, jax.devices()
    mesh = jax.make_mesh((4,), ("i",))
    g = random_graph(1024, 8192, seed=1)
    sess = StreamSession(g, mesh=mesh, d_p=16, tile=64,
                         guard=GuardConfig())
    # healthy batch: clean word
    sess.apply(random_batch(g, 32, seed=2))
    assert sess.history[-1].health == 0, sess.history[-1]
    # NaN-poison a lane: sharded solve must flag + the ladder (sharded
    # retry -> recompute) must recover
    chaos = ChaosMonkey(seed=3)
    sess.ranks = chaos.poison_ranks(sess.ranks, mode="nan", k=1, idx=[5])
    sess.apply(random_batch(sess.snap.graph(), 16, seed=4))
    st = sess.history[-1]
    assert st.health & H_NONFINITE, st
    assert st.escalations >= 1
    err = l1_error(np.asarray(sess.flat_ranks()),
                   np.asarray(sess.static_reference()))
    assert err < 1e-8, err
    print("OK")
""")


@pytest.mark.slow
def test_sharded_guarded_session_4dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
