#!/usr/bin/env bash
# Tier-1 verify in one command: `bash test.sh` (or `bash test.sh tests/test_stream.py`).
set -euo pipefail

export JAX_ENABLE_X64=1  # allow fp64 (paper uses 64-bit ranks; tau < f32 eps)
REPO_DIR="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
export PYTHONPATH="${REPO_DIR}/src${PYTHONPATH:+:$PYTHONPATH}"

/usr/bin/env python3 -m pytest -x -q "$@"
