#!/usr/bin/env bash
# Tier-1 verify in one command: `bash test.sh` (or `bash test.sh tests/test_stream.py`).
set -euo pipefail

export JAX_ENABLE_X64=1  # allow fp64 (paper uses 64-bit ranks; tau < f32 eps)
REPO_DIR="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
export PYTHONPATH="${REPO_DIR}/src${PYTHONPATH:+:$PYTHONPATH}"

/usr/bin/env python3 -m pytest -x -q "$@"

# Bench regression gate (smoke frontier bench vs the committed seed; +200%
# because hosts differ — catastrophic-only, like CI). Opt out with
# REPRO_SKIP_BENCH_GATE=1 for pure unit-test iterations.
if [[ "${REPRO_SKIP_BENCH_GATE:-0}" != "1" && $# -eq 0 ]]; then
  BENCH_OUT="$(mktemp -t bench_gate.XXXXXX.json)"
  trap 'rm -f "${BENCH_OUT}"' EXIT
  (cd "${REPO_DIR}" && /usr/bin/env python3 -m benchmarks.run frontier \
      --smoke --name test-sh-gate --out "${BENCH_OUT}" --pr-json '' \
      >/dev/null)
  /usr/bin/env python3 -m repro.obs.check "${BENCH_OUT}" --against seed \
      --threshold 2.0 --only frontier/
fi
